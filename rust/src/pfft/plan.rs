//! The distributed FFT plan: alignment states, redistribution schedule and
//! the forward/backward drivers (paper §3.3, §3.5, §3.6), generic over the
//! [`Real`] precision.
//!
//! A `d`-dimensional global array on an `r`-dimensional process grid
//! (`r <= d-1`) passes through `r+1` *alignment states* `t = r, ..., 0`:
//!
//! * state `t`: axes `0..t` are distributed over grid directions `0..t`,
//!   axis `t` is locally complete, axes `t+1..=r` are distributed over grid
//!   directions `t..r`, and axes beyond `r` are complete.
//! * state `r` (the input layout) has all trailing axes `r..d` complete —
//!   these are transformed first.
//! * the exchange `t+1 -> t` is a global redistribution within the 1-D
//!   process subgroup of grid direction `t` (the paper's key observation in
//!   §3.5: a pencil/general decomposition is a *collection of slab
//!   decompositions* over the direction subgroups).
//!
//! A forward transform is then `d` partial serial FFTs interleaved with `r`
//! redistributions — Eqs. (12–14) for slabs, (21–25) for pencils, (26–32)
//! for the 4-D/3-D-grid case — and the backward transform retraces the
//! sequence exactly.
//!
//! The precision is a *plan* property: a `PfftPlan<f32>` builds `f32`
//! twiddle tables and `Complex32` buffers, and its redistribution plans are
//! compiled for 8-byte elements — halving every wire byte of the exchange
//! relative to the default `PfftPlan<f64>`.

use std::time::Instant;

use crate::decomp::local_len;
use crate::fft::{Complex, Direction, Real, SerialFft};
use crate::redistribute::{HierarchicalPlan, PipelinedRedistPlan, RedistPlan, TraditionalPlan};
use crate::simmpi::topology::{subcomms_with_dims, CartComm};
use crate::simmpi::{dims_create, ranks_per_node_from_env, Comm, Pod, Transport};

/// Which global redistribution implementation a plan uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RedistMethod {
    /// The paper's method: one `alltoallw` over subarray datatypes.
    Alltoallw,
    /// The baseline: local transpose + `alltoallv` of contiguous buffers.
    Traditional,
    /// The topology-aware two-phase exchange
    /// ([`crate::redistribute::HierarchicalPlan`]): intra-node aggregation
    /// over the shared window, one combined message per node pair, direct
    /// scatter into pencils. Node grouping comes from the plan's
    /// `ranks_per_node` (see [`PfftPlan::with_topology`]).
    Hierarchical,
}

impl RedistMethod {
    /// Stable name for labels, JSON rows and wisdom entries.
    pub fn name(self) -> &'static str {
        match self {
            RedistMethod::Alltoallw => "alltoallw",
            RedistMethod::Traditional => "traditional",
            RedistMethod::Hierarchical => "hierarchical",
        }
    }

    /// Parse a CLI/wisdom spelling.
    pub fn parse(s: &str) -> Option<RedistMethod> {
        match s {
            "alltoallw" | "a2aw" | "new" => Some(RedistMethod::Alltoallw),
            "traditional" | "trad" => Some(RedistMethod::Traditional),
            "hierarchical" | "hier" | "two-level" => Some(RedistMethod::Hierarchical),
            _ => None,
        }
    }
}

/// How the redistribution steps of a transform are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// One blocking collective per redistribution (the paper's protocol).
    #[default]
    Blocking,
    /// The pipelined engine ([`PipelinedRedistPlan`]): every
    /// redistribution is split into `depth` sub-exchanges issued as
    /// persistent nonblocking collectives, and the serial FFT of each
    /// already-received chunk overlaps the communication of the chunks
    /// still in flight. Requires [`RedistMethod::Alltoallw`].
    /// `depth == 1` (or a redistribution with no free axis to chunk, e.g.
    /// 2-D arrays) degrades to `Blocking` behaviour.
    Pipelined {
        /// Chunk count and in-flight window of the pipeline
        /// (`overlap_depth` in the CLI).
        depth: usize,
    },
}

impl ExecMode {
    /// Stable name for labels, JSON rows and wisdom entries (the depth is
    /// carried separately via [`ExecMode::depth`]).
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Blocking => "blocking",
            ExecMode::Pipelined { .. } => "pipelined",
        }
    }

    /// Overlap depth of the pipelined mode (`0` for blocking).
    pub fn depth(self) -> usize {
        match self {
            ExecMode::Blocking => 0,
            ExecMode::Pipelined { depth } => depth,
        }
    }
}

enum RedistKind {
    New(RedistPlan),
    Trad(TraditionalPlan),
    Piped(PipelinedRedistPlan),
    Hier(HierarchicalPlan),
}

impl RedistKind {
    // Plans own their execution state (staging arenas, in-flight windows),
    // so execution takes `&mut self` across every kind. The element type is
    // a call-site parameter: the plans are compiled for an element *size*
    // and move bytes.
    fn execute<E: Pod>(&mut self, a: &[E], b: &mut [E]) {
        match self {
            RedistKind::New(p) => p.execute(a, b),
            RedistKind::Trad(p) => p.execute(a, b),
            RedistKind::Piped(p) => p.execute(a, b),
            RedistKind::Hier(p) => p.execute(a, b),
        }
    }

    fn execute_back<E: Pod>(&mut self, b: &[E], a: &mut [E]) {
        match self {
            RedistKind::New(p) => p.execute_back(b, a),
            RedistKind::Trad(p) => p.execute_back(b, a),
            RedistKind::Piped(p) => p.execute_back(b, a),
            RedistKind::Hier(p) => p.execute_back(b, a),
        }
    }
}

/// Wall-clock accounting per transform phase — the paper's Figs. 6–10
/// report (a) total, (b) redistribution, (c) serial FFT. Pipelined
/// execution attributes its time to the `overlap_*` buckets instead:
/// `overlap_fft` is the compute spent inside per-chunk serial FFTs and
/// `overlap_comm` is the *exposed* communication (wait + chunk
/// gather/scatter) around it — their sum is the wall time of the
/// overlapped stages, so `overlap_comm` shrinking relative to a blocking
/// run's `redist` is the overlap win.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimers {
    /// Seconds inside serial FFT calls (non-overlapped stages).
    pub fft: f64,
    /// Seconds inside blocking global redistributions.
    pub redist: f64,
    /// Seconds inside per-chunk serial FFTs of pipelined stages.
    pub overlap_fft: f64,
    /// Exposed (non-hidden) communication seconds of pipelined stages.
    pub overlap_comm: f64,
}

impl StageTimers {
    pub fn total(&self) -> f64 {
        self.fft + self.redist + self.overlap_fft + self.overlap_comm
    }

    pub fn reset(&mut self) {
        *self = StageTimers::default();
    }
}

/// Transform kind of a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Complex-to-complex in both directions.
    C2c,
    /// Real-to-complex forward / complex-to-real backward (Hermitian halved
    /// last axis, like the paper's benchmark transforms).
    R2c,
}

impl Kind {
    /// Stable name for labels, JSON rows and wisdom signatures.
    pub fn name(self) -> &'static str {
        match self {
            Kind::C2c => "c2c",
            Kind::R2c => "r2c",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "c2c" => Some(Kind::C2c),
            "r2c" => Some(Kind::R2c),
            _ => None,
        }
    }
}

/// A distributed multidimensional FFT plan over a Cartesian process grid,
/// at precision `T` (default `f64`).
///
/// Created collectively by every rank of `comm`; holds the per-rank local
/// buffers, the redistribution plans for every alignment step, and stage
/// timers. Drive it with [`PfftPlan::forward`] / [`PfftPlan::backward`].
///
/// Each redistribution plan carries its *compiled* execution state —
/// flattened datatypes, fused [`crate::simmpi::TransferPlan`]s, staging
/// arenas and chunk scratch — created once here for `size_of::<Complex<T>>`
/// elements and reused by every forward/backward transform across all
/// alignment stages, so steady-state transforms do not re-flatten datatypes
/// or reallocate staging.
pub struct PfftPlan<T = f64> {
    /// Global *real-space* shape (for `C2c` this equals the complex shape).
    global: Vec<usize>,
    /// Global complex shape (last axis halved for `R2c`).
    global_c: Vec<usize>,
    kind: Kind,
    /// Grid extents (`r = dims.len()` directions).
    dims: Vec<usize>,
    /// This rank's grid coordinates.
    coords: Vec<usize>,
    /// Local complex shape at every alignment state `t = 0..=r`.
    shapes: Vec<Vec<usize>>,
    /// `redists[t]` exchanges state `t+1` (v-aligned, v = t+1) with state
    /// `t` (w-aligned, w = t), within direction subgroup `t`.
    redists: Vec<RedistKind>,
    /// Work buffers, one per state.
    bufs: Vec<Vec<Complex<T>>>,
    /// Local real shape at state `r` (`R2c` only).
    real_shape: Vec<usize>,
    /// Which redistribution implementation the plan compiled.
    method: RedistMethod,
    /// How redistributions are executed (blocking vs pipelined).
    exec: ExecMode,
    /// Which transport redistribution payloads move through.
    transport: Transport,
    /// Simulated node width (consecutive ranks per node) the plan was
    /// compiled for, and the resulting node count over the full group.
    ranks_per_node: usize,
    nodes: usize,
    pub timers: StageTimers,
}

impl<T: Real> PfftPlan<T> {
    /// Plan a transform of the global array `global` over an
    /// `grid_ndims`-dimensional process grid with extents from
    /// `dims_create`, using the paper's `alltoallw` redistribution.
    pub fn new(comm: &Comm, global: &[usize], grid_ndims: usize, kind: Kind) -> PfftPlan<T> {
        let dims = dims_create(comm.size(), grid_ndims);
        Self::with_dims(comm, global, &dims, kind, RedistMethod::Alltoallw)
    }

    /// Full-control constructor: explicit grid extents and redistribution
    /// method. `dims.len() <= global.len() - 1` so at least one axis starts
    /// locally complete.
    pub fn with_dims(
        comm: &Comm,
        global: &[usize],
        dims: &[usize],
        kind: Kind,
        method: RedistMethod,
    ) -> PfftPlan<T> {
        Self::with_exec(comm, global, dims, kind, method, ExecMode::Blocking)
    }

    /// [`PfftPlan::with_dims`] plus an explicit [`ExecMode`].
    /// `ExecMode::Pipelined` requires [`RedistMethod::Alltoallw`] (the
    /// traditional baseline has no nonblocking schedule).
    pub fn with_exec(
        comm: &Comm,
        global: &[usize],
        dims: &[usize],
        kind: Kind,
        method: RedistMethod,
        exec: ExecMode,
    ) -> PfftPlan<T> {
        Self::with_transport(comm, global, dims, kind, method, exec, Transport::Mailbox)
    }

    /// [`PfftPlan::with_exec`] plus an explicit payload [`Transport`] for
    /// every redistribution plan. [`Transport::Window`] (the one-copy
    /// shared-window engine) requires [`RedistMethod::Alltoallw`] or
    /// [`RedistMethod::Hierarchical`] — the traditional baseline's
    /// contiguous `alltoallv` stays on the mailbox, as in the libraries it
    /// models. The node grouping for hierarchical plans defaults to the
    /// `A2WFFT_RANKS_PER_NODE` environment variable (1 when unset).
    pub fn with_transport(
        comm: &Comm,
        global: &[usize],
        dims: &[usize],
        kind: Kind,
        method: RedistMethod,
        exec: ExecMode,
        transport: Transport,
    ) -> PfftPlan<T> {
        let rpn = ranks_per_node_from_env();
        Self::with_topology(comm, global, dims, kind, method, exec, transport, rpn)
    }

    /// Fullest constructor: [`PfftPlan::with_transport`] plus an explicit
    /// `ranks_per_node` node grouping (consecutive ranks per simulated
    /// node) consumed by [`RedistMethod::Hierarchical`] redistribution
    /// plans. The grouping is recorded for any method (it is a property of
    /// the simulated machine, reported as the `nodes` column), but only
    /// hierarchical plans change behaviour with it.
    #[allow(clippy::too_many_arguments)]
    pub fn with_topology(
        comm: &Comm,
        global: &[usize],
        dims: &[usize],
        kind: Kind,
        method: RedistMethod,
        exec: ExecMode,
        transport: Transport,
        ranks_per_node: usize,
    ) -> PfftPlan<T> {
        let ranks_per_node = ranks_per_node.max(1);
        let d = global.len();
        let r = dims.len();
        assert!(d >= 2, "pfft: need at least 2 dimensions");
        assert!(r >= 1 && r <= d - 1, "pfft: grid rank {r} out of range for array rank {d}");
        assert_eq!(dims.iter().product::<usize>(), comm.size(), "pfft: grid size != comm size");
        if kind == Kind::R2c {
            assert!(global[d - 1] >= 2, "pfft: r2c needs last axis >= 2");
        }
        let mut global_c = global.to_vec();
        if kind == Kind::R2c {
            global_c[d - 1] = global[d - 1] / 2 + 1;
        }
        let cart = CartComm::create(comm, dims);
        let coords = cart.coords().to_vec();
        let subs = subcomms_with_dims(comm, dims);
        // Local complex shape at each alignment state.
        let shapes: Vec<Vec<usize>> = (0..=r)
            .map(|t| {
                (0..d)
                    .map(|a| {
                        if a < t {
                            local_len(global_c[a], dims[a], coords[a])
                        } else if a == t {
                            global_c[a]
                        } else if a <= r {
                            local_len(global_c[a], dims[a - 1], coords[a - 1])
                        } else {
                            global_c[a]
                        }
                    })
                    .collect()
            })
            .collect();
        // Redistribution plans: state t+1 -> state t over subgroup t,
        // v = t+1 (aligned in A), w = t (aligned in B).
        if let ExecMode::Pipelined { .. } = exec {
            assert_eq!(
                method,
                RedistMethod::Alltoallw,
                "pfft: ExecMode::Pipelined requires RedistMethod::Alltoallw"
            );
        }
        // Graceful transport degradation: a window-transport request that
        // cannot be honoured (traditional method has no plan-based
        // exchange; the exposure hub's peer bitmask caps a subgroup at 128
        // ranks) falls back to the mailbox with a logged downgrade instead
        // of failing plan construction — `PfftPlan::tuned` always yields a
        // working plan.
        let transport = if transport == Transport::Window {
            let too_wide = subs.iter().any(|s| s.size() > 128);
            if method == RedistMethod::Traditional {
                if comm.rank() == 0 {
                    eprintln!(
                        "pfft: warning: Transport::Window is not available for \
                         RedistMethod::Traditional; downgrading to Transport::Mailbox"
                    );
                }
                Transport::Mailbox
            } else if too_wide {
                if comm.rank() == 0 {
                    eprintln!(
                        "pfft: warning: Transport::Window caps a redistribution subgroup \
                         at 128 ranks; downgrading to Transport::Mailbox"
                    );
                }
                Transport::Mailbox
            } else {
                Transport::Window
            }
        } else {
            transport
        };
        let elem = std::mem::size_of::<Complex<T>>();
        let redists: Vec<RedistKind> = (0..r)
            .map(|t| {
                let (a, b) = (&shapes[t + 1], &shapes[t]);
                match (method, exec) {
                    (RedistMethod::Alltoallw, ExecMode::Pipelined { depth }) if depth > 1 => {
                        RedistKind::Piped(PipelinedRedistPlan::with_transport(
                            &subs[t],
                            elem,
                            a,
                            t + 1,
                            b,
                            t,
                            depth,
                            depth,
                            transport,
                        ))
                    }
                    (RedistMethod::Alltoallw, _) => RedistKind::New(RedistPlan::with_transport(
                        &subs[t],
                        elem,
                        a,
                        t + 1,
                        b,
                        t,
                        transport,
                    )),
                    (RedistMethod::Traditional, _) => {
                        RedistKind::Trad(TraditionalPlan::new(&subs[t], elem, a, t + 1, b, t))
                    }
                    (RedistMethod::Hierarchical, _) => {
                        RedistKind::Hier(HierarchicalPlan::with_transport(
                            &subs[t],
                            elem,
                            a,
                            t + 1,
                            b,
                            t,
                            transport,
                            ranks_per_node,
                        ))
                    }
                }
            })
            .collect();
        let bufs: Vec<Vec<Complex<T>>> =
            shapes.iter().map(|s| vec![Complex::<T>::ZERO; s.iter().product()]).collect();
        // Real-space local shape at state r (axes 0..r distributed).
        let real_shape: Vec<usize> = (0..d)
            .map(|a| if a < r { local_len(global[a], dims[a], coords[a]) } else { global[a] })
            .collect();
        PfftPlan {
            global: global.to_vec(),
            global_c,
            kind,
            dims: dims.to_vec(),
            coords,
            shapes,
            redists,
            bufs,
            real_shape,
            method,
            exec,
            transport,
            ranks_per_node,
            nodes: comm.size().div_ceil(ranks_per_node),
            timers: StageTimers::default(),
        }
    }

    /// Which redistribution implementation this plan compiled.
    pub fn method(&self) -> RedistMethod {
        self.method
    }

    /// Metric labels of this plan's exchange configuration.
    fn exchange_labels(&self) -> crate::metrics::Labels {
        [
            ("method", self.method.name()),
            ("transport", self.transport.name()),
            ("exec", self.exec.name()),
        ]
    }

    /// How this plan executes its redistributions.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec
    }

    /// Which transport redistribution payloads move through.
    pub fn transport(&self) -> Transport {
        self.transport
    }

    /// Simulated node width (consecutive ranks per node) this plan was
    /// compiled for (1 = flat machine).
    pub fn ranks_per_node(&self) -> usize {
        self.ranks_per_node
    }

    /// Number of simulated nodes over the full process group.
    pub fn node_count(&self) -> usize {
        self.nodes
    }

    /// Dtype name of this plan's precision (`"f32"`/`"f64"`).
    pub fn dtype_name(&self) -> &'static str {
        T::NAME
    }

    /// Grid extents.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// This rank's grid coordinates.
    pub fn coords(&self) -> &[usize] {
        &self.coords
    }

    /// Global real-space shape.
    pub fn global(&self) -> &[usize] {
        &self.global
    }

    /// Local *real-space* input shape (state `r`): what
    /// [`PfftPlan::forward_r2c`] consumes and what [`PfftPlan::forward`]
    /// consumes for `C2c` plans.
    pub fn input_shape(&self) -> &[usize] {
        match self.kind {
            Kind::C2c => &self.shapes[self.dims.len()],
            Kind::R2c => &self.real_shape,
        }
    }

    /// Local spectral-space output shape (state `0`).
    pub fn output_shape(&self) -> &[usize] {
        &self.shapes[0]
    }

    /// Local input element count.
    pub fn input_len(&self) -> usize {
        self.input_shape().iter().product()
    }

    /// Local output element count.
    pub fn output_len(&self) -> usize {
        self.output_shape().iter().product()
    }

    /// Per-axis `(start, len)` global window of this rank's *input* block
    /// (real-space window for `R2c` plans).
    pub fn input_window(&self) -> Vec<(usize, usize)> {
        let r = self.dims.len();
        (0..self.global.len())
            .map(|a| {
                if a < r {
                    let (n, s) = crate::decomp::decompose(self.global[a], self.dims[a], self.coords[a]);
                    (s, n)
                } else {
                    (0, self.global[a])
                }
            })
            .collect()
    }

    /// Per-axis `(start, len)` global window of this rank's *output* block
    /// (in the complex global shape — last axis halved for `R2c`).
    pub fn output_window(&self) -> Vec<(usize, usize)> {
        let r = self.dims.len();
        (0..self.global_c.len())
            .map(|a| {
                if a == 0 || a > r {
                    (0, self.global_c[a])
                } else {
                    let (n, s) =
                        crate::decomp::decompose(self.global_c[a], self.dims[a - 1], self.coords[a - 1]);
                    (s, n)
                }
            })
            .collect()
    }

    /// Forward complex transform: `input` in state-`r` layout (shape
    /// [`PfftPlan::input_shape`]), `output` in state-0 layout.
    pub fn forward(&mut self, engine: &mut dyn SerialFft<T>, input: &[Complex<T>], output: &mut [Complex<T>]) {
        assert_eq!(self.kind, Kind::C2c, "forward: use forward_r2c on an R2c plan");
        let r = self.dims.len();
        let d = self.global.len();
        assert_eq!(input.len(), self.input_len(), "forward: input length");
        assert_eq!(output.len(), self.output_len(), "forward: output length");
        self.bufs[r].copy_from_slice(input);
        // Transform all trailing complete axes at state r.
        let t0 = Instant::now();
        {
            let shape = self.shapes[r].clone();
            for axis in (r..d).rev() {
                crate::trace_span!(Fft, crate::trace::axis_label(axis));
                engine.c2c(&mut self.bufs[r], &shape, axis, Direction::Forward);
            }
        }
        self.timers.fft += t0.elapsed().as_secs_f64();
        self.descend(engine, Direction::Forward);
        output.copy_from_slice(&self.bufs[0]);
    }

    /// Backward complex transform: `input` in state-0 layout, `output` in
    /// state-`r` layout. Scales by `1/prod(N)` (numpy `ifftn` convention).
    pub fn backward(&mut self, engine: &mut dyn SerialFft<T>, input: &[Complex<T>], output: &mut [Complex<T>]) {
        assert_eq!(self.kind, Kind::C2c, "backward: use backward_c2r on an R2c plan");
        let r = self.dims.len();
        let d = self.global.len();
        assert_eq!(input.len(), self.output_len(), "backward: input length");
        assert_eq!(output.len(), self.input_len(), "backward: output length");
        self.bufs[0].copy_from_slice(input);
        self.ascend(engine);
        let t0 = Instant::now();
        {
            let shape = self.shapes[r].clone();
            for axis in r..d {
                crate::trace_span!(Fft, crate::trace::axis_label(axis));
                engine.c2c(&mut self.bufs[r], &shape, axis, Direction::Backward);
            }
        }
        self.timers.fft += t0.elapsed().as_secs_f64();
        output.copy_from_slice(&self.bufs[r]);
    }

    /// Forward real-to-complex transform (paper's benchmark workload):
    /// `input` real in state-`r` layout (shape [`PfftPlan::input_shape`]),
    /// `output` complex in state-0 layout with halved last axis.
    pub fn forward_r2c(&mut self, engine: &mut dyn SerialFft<T>, input: &[T], output: &mut [Complex<T>]) {
        assert_eq!(self.kind, Kind::R2c, "forward_r2c: plan is not R2c");
        let r = self.dims.len();
        let d = self.global.len();
        assert_eq!(input.len(), self.input_len(), "forward_r2c: input length");
        assert_eq!(output.len(), self.output_len(), "forward_r2c: output length");
        let t0 = Instant::now();
        {
            // r2c along the last axis into the state-r complex buffer...
            let rs = self.real_shape.clone();
            {
                crate::trace_span!(Fft, "r2c");
                engine.r2c(input, &rs, &mut self.bufs[r]);
            }
            // ...then c2c on the remaining complete axes.
            let shape = self.shapes[r].clone();
            for axis in (r..d - 1).rev() {
                crate::trace_span!(Fft, crate::trace::axis_label(axis));
                engine.c2c(&mut self.bufs[r], &shape, axis, Direction::Forward);
            }
        }
        self.timers.fft += t0.elapsed().as_secs_f64();
        self.descend(engine, Direction::Forward);
        output.copy_from_slice(&self.bufs[0]);
    }

    /// Backward complex-to-real transform, inverse of
    /// [`PfftPlan::forward_r2c`] including the `1/prod(N)` scaling.
    pub fn backward_c2r(&mut self, engine: &mut dyn SerialFft<T>, input: &[Complex<T>], output: &mut [T]) {
        assert_eq!(self.kind, Kind::R2c, "backward_c2r: plan is not R2c");
        let r = self.dims.len();
        let d = self.global.len();
        assert_eq!(input.len(), self.output_len(), "backward_c2r: input length");
        assert_eq!(output.len(), self.input_len(), "backward_c2r: output length");
        self.bufs[0].copy_from_slice(input);
        self.ascend(engine);
        let t0 = Instant::now();
        {
            let shape = self.shapes[r].clone();
            for axis in r..d - 1 {
                crate::trace_span!(Fft, crate::trace::axis_label(axis));
                engine.c2c(&mut self.bufs[r], &shape, axis, Direction::Backward);
            }
            let rs = self.real_shape.clone();
            {
                crate::trace_span!(Fft, "c2r");
                engine.c2r(&self.bufs[r], &rs, output);
            }
        }
        self.timers.fft += t0.elapsed().as_secs_f64();
    }

    /// Forward alignment walk: states `r-1, ..., 0`; exchange into state
    /// `t`, then transform axis `t`.
    ///
    /// In `ExecMode::Pipelined`, the exchange and the axis-`t` transform
    /// are fused: the serial FFT runs on every dense chunk as soon as its
    /// sub-exchange completes, while later chunks are still in flight.
    /// The per-line transforms are identical either way, so the spectra
    /// are bitwise equal across modes.
    ///
    /// Lane batching and the per-rank worker pool live *inside*
    /// [`SerialFft::c2c`] (see [`crate::fft::EngineCfg`]), so every chunk
    /// callback here is transparently batched/parallelized too — the
    /// pipelined per-chunk compute overlaps a pooled FFT with the
    /// in-flight sub-exchanges without any code on this side.
    fn descend(&mut self, engine: &mut dyn SerialFft<T>, dir: Direction) {
        let r = self.dims.len();
        let labels = self.exchange_labels();
        for t in (0..r).rev() {
            let (lo, hi) = self.bufs.split_at_mut(t + 1);
            match &mut self.redists[t] {
                RedistKind::Piped(p) => {
                    crate::trace_span!(Exchange, "exchange_pipelined");
                    let mut fft_s = 0.0f64;
                    let t0 = Instant::now();
                    p.execute_chunked(&hi[0], &mut lo[t], |chunk, shape| {
                        let tc = Instant::now();
                        {
                            crate::trace_span!(Fft, "chunk_c2c");
                            engine.c2c(chunk, shape, t, dir);
                        }
                        fft_s += tc.elapsed().as_secs_f64();
                    });
                    let wall = t0.elapsed();
                    self.timers.overlap_fft += fft_s;
                    self.timers.overlap_comm += wall.as_secs_f64() - fft_s;
                    crate::metrics::observe_ns(
                        "a2wfft_exchange_seconds",
                        labels,
                        wall.as_nanos() as u64,
                    );
                }
                blocking => {
                    let t0 = Instant::now();
                    {
                        crate::trace_span!(Exchange, "exchange");
                        blocking.execute(&hi[0], &mut lo[t]);
                    }
                    let redist = t0.elapsed();
                    self.timers.redist += redist.as_secs_f64();
                    crate::metrics::observe_ns(
                        "a2wfft_exchange_seconds",
                        labels,
                        redist.as_nanos() as u64,
                    );
                    let t1 = Instant::now();
                    let shape = self.shapes[t].clone();
                    {
                        crate::trace_span!(Fft, crate::trace::axis_label(t));
                        engine.c2c(&mut lo[t], &shape, t, dir);
                    }
                    let fft = t1.elapsed();
                    self.timers.fft += fft.as_secs_f64();
                    crate::metrics::observe_ns(
                        "a2wfft_fft_axis_seconds",
                        crate::metrics::label1("dtype", T::NAME),
                        fft.as_nanos() as u64,
                    );
                }
            }
        }
    }

    /// Backward alignment walk: states `0, ..., r-1`; inverse-transform
    /// axis `t`, then exchange back into state `t+1`. Pipelined plans fuse
    /// the two: each chunk is inverse-transformed and posted while the
    /// previous chunk's exchange drains.
    fn ascend(&mut self, engine: &mut dyn SerialFft<T>) {
        let r = self.dims.len();
        let labels = self.exchange_labels();
        for t in 0..r {
            let (lo, hi) = self.bufs.split_at_mut(t + 1);
            match &mut self.redists[t] {
                RedistKind::Piped(p) => {
                    crate::trace_span!(Exchange, "exchange_back_pipelined");
                    let mut fft_s = 0.0f64;
                    let t0 = Instant::now();
                    p.execute_back_chunked(&lo[t], &mut hi[0], |chunk, shape| {
                        let tc = Instant::now();
                        {
                            crate::trace_span!(Fft, "chunk_c2c_inv");
                            engine.c2c(chunk, shape, t, Direction::Backward);
                        }
                        fft_s += tc.elapsed().as_secs_f64();
                    });
                    let wall = t0.elapsed();
                    self.timers.overlap_fft += fft_s;
                    self.timers.overlap_comm += wall.as_secs_f64() - fft_s;
                    crate::metrics::observe_ns(
                        "a2wfft_exchange_seconds",
                        labels,
                        wall.as_nanos() as u64,
                    );
                }
                blocking => {
                    let t0 = Instant::now();
                    let shape = self.shapes[t].clone();
                    {
                        crate::trace_span!(Fft, crate::trace::axis_label(t));
                        engine.c2c(&mut lo[t], &shape, t, Direction::Backward);
                    }
                    let fft = t0.elapsed();
                    self.timers.fft += fft.as_secs_f64();
                    crate::metrics::observe_ns(
                        "a2wfft_fft_axis_seconds",
                        crate::metrics::label1("dtype", T::NAME),
                        fft.as_nanos() as u64,
                    );
                    let t1 = Instant::now();
                    {
                        crate::trace_span!(Exchange, "exchange_back");
                        blocking.execute_back(&lo[t], &mut hi[0]);
                    }
                    let redist = t1.elapsed();
                    self.timers.redist += redist.as_secs_f64();
                    crate::metrics::observe_ns(
                        "a2wfft_exchange_seconds",
                        labels,
                        redist.as_nanos() as u64,
                    );
                }
            }
        }
    }
}
