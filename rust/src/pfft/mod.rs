//! Parallel multidimensional FFT driver — slab (§3.3), pencil (§3.5) and
//! general higher-dimensional (§3.6) decompositions over the global
//! redistribution engine of [`crate::redistribute`].
//!
//! The decomposition dimensionality is a parameter, not a code path: a slab
//! plan is a pencil plan with a 1-D grid, the paper's 4-D proof-of-concept
//! is the same plan with a 3-D grid. See [`PfftPlan`].
//!
//! Redistributions run either as blocking collectives
//! ([`ExecMode::Blocking`], the paper's protocol) or through the pipelined
//! overlap engine ([`ExecMode::Pipelined`]), which hides communication
//! behind the serial FFT of already-received chunks.

pub mod plan;

pub use plan::{ExecMode, Kind, PfftPlan, RedistMethod, StageTimers};
