//! Always-on metrics: per-rank counters, gauges and log-bucketed
//! HDR-style latency histograms, plus the failure flight recorder.
//!
//! The span tracer (`crate::trace`) answers *where time went in one run*;
//! this module answers *what the latency distribution of each hot
//! boundary is* — p50/p99 exchange latency per (method × transport ×
//! exec), copy-engine timings, axis-pass durations, queue depths, watchdog
//! near-miss margins, fault retry counts — cheaply enough to stay on in
//! production runs.
//!
//! Design contract (mirrors the PR-2 and PR-6 invariants):
//!
//! * **Disabled cost is one relaxed atomic load** per instrumentation
//!   site. [`timer`] returns `None` without touching the clock.
//! * **Allocation-free after warm-up**: each thread owns a fixed-capacity
//!   registry of slots; a slot's bucket array is allocated the first time
//!   its `(name, labels)` key is seen and reused forever after. Steady
//!   state records are a thread-local lookup (pointer-compared `&'static`
//!   keys) plus one bucket increment.
//! * **Mergeable**: histograms are fixed log-bucketed arrays (8 linear
//!   sub-buckets per octave), so cross-thread and cross-rank reduction is
//!   elementwise addition — associative and deterministic.
//!
//! At world teardown every rank serializes its registry and ships it to
//! rank 0 ([`rank_flush`], the same collective pattern as the trace
//! gather), which merges into a process-wide table. Three exports:
//!
//! * [`summaries`] — per-histogram count/p50/p90/p99/max for the
//!   `metrics` block of `RunReport` / `--json` rows;
//! * [`render_prometheus`] — Prometheus text format for
//!   `--metrics-out PATH`;
//! * the **flight recorder** — a small process-wide ring of recent span
//!   labels ([`flight_note`]) snapshotted on rank death or watchdog abort
//!   ([`flight_capture`]) and dumped into the structured `failure` JSON,
//!   so every chaos failure is post-hoc diagnosable.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::simmpi::Comm;

/// Wire tag of the teardown gather; disjoint from user tags, the
/// nonblocking tag space (`0xC000_0000+`) and the trace gather
/// (`0x8000_007E`).
const TAG_METRICS: u32 = 0x8000_007D;

/// Linear sub-buckets per octave: 8, i.e. ≤12.5% relative quantile error.
const SUB_BITS: usize = 3;
const SUBS: usize = 1 << SUB_BITS;
/// Bucket groups (group 0 is the linear 0..8 range, then one per octave).
const GROUPS: usize = 36;
/// Total buckets per histogram; the last bucket absorbs every larger
/// value (the exact maximum is tracked separately).
pub const BUCKETS: usize = GROUPS * SUBS;

/// Per-thread slot capacity. A full run uses a few dozen distinct keys;
/// overflowing records are dropped and counted, never allocated.
const MAX_SLOTS: usize = 96;

/// Flight-recorder depth: enough to cover the last few transform stages
/// of every rank without unbounded growth.
pub const FLIGHT_CAP: usize = 128;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is the metrics registry recording? One relaxed load — the whole cost
/// of a disabled instrumentation site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn metrics on or off, process-wide. Flip it **outside**
/// `World::run` so every rank agrees (the teardown gather is collective).
pub fn set_enabled(on: bool) {
    if on {
        let _ = flight_epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Up to three `(label_name, label_value)` pairs; empty-name pairs are
/// unused. Values must be `'static` (method/transport/exec names are) so
/// recording never allocates.
pub type Labels = [(&'static str, &'static str); 3];

/// No labels at all.
pub const NO_LABELS: Labels = [("", ""); 3];

/// One label pair.
pub const fn label1(k: &'static str, v: &'static str) -> Labels {
    [(k, v), ("", ""), ("", "")]
}

/// What a slot is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Log-bucketed histogram of nanosecond durations (exported in
    /// seconds).
    HistNs = 0,
    /// Log-bucketed histogram of unit-less magnitudes (depths, counts).
    HistUnits = 1,
    /// Monotonic counter.
    Counter = 2,
    /// Last-write gauge (merged by maximum, for determinism).
    Gauge = 3,
}

impl Kind {
    fn from_u64(v: u64) -> Kind {
        match v {
            0 => Kind::HistNs,
            1 => Kind::HistUnits,
            2 => Kind::Counter,
            _ => Kind::Gauge,
        }
    }

    fn is_hist(self) -> bool {
        matches!(self, Kind::HistNs | Kind::HistUnits)
    }
}

/// Bucket index of a value: exact below 8, then 8 linear sub-buckets per
/// octave. Monotone in `v`; everything above the tracked range lands in
/// the last bucket.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize;
    let group = msb - SUB_BITS + 1;
    let sub = ((v >> (msb - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
    (group * SUBS + sub).min(BUCKETS - 1)
}

/// Inclusive upper bound of bucket `b` (the Prometheus `le` value).
fn bucket_upper(b: usize) -> u64 {
    let group = b / SUBS;
    let sub = (b % SUBS) as u64;
    if group == 0 {
        sub
    } else {
        ((SUBS as u64 + sub + 1) << (group - 1)) - 1
    }
}

struct Slot {
    name: &'static str,
    labels: Labels,
    kind: Kind,
    count: u64,
    /// Sum of recorded values (histograms/counters); last/greatest value
    /// for gauges.
    sum: u64,
    max: u64,
    /// Allocated once at slot creation for histogram kinds.
    buckets: Option<Box<[u64; BUCKETS]>>,
}

struct Registry {
    slots: Vec<Slot>,
    /// Records refused because every slot was taken.
    overflow: u64,
}

impl Registry {
    fn new() -> Registry {
        Registry { slots: Vec::with_capacity(MAX_SLOTS), overflow: 0 }
    }

    #[inline]
    fn slot_mut(&mut self, name: &'static str, labels: Labels, kind: Kind) -> Option<&mut Slot> {
        // Pointer-first key comparison: the same call site passes the same
        // `&'static str` literals, so the fast path never compares bytes.
        let pos = self.slots.iter().position(|s| {
            std::ptr::eq(s.name, name) && labels.iter().zip(s.labels.iter()).all(|(a, b)| {
                std::ptr::eq(a.0, b.0) && std::ptr::eq(a.1, b.1)
            })
        });
        let pos = match pos {
            Some(p) => Some(p),
            // Slow path (first record from a new call site / monomorphized
            // twin): compare by content before concluding the key is new.
            None => self
                .slots
                .iter()
                .position(|s| s.name == name && s.labels == labels),
        };
        match pos {
            Some(p) => Some(&mut self.slots[p]),
            None => {
                if self.slots.len() >= MAX_SLOTS {
                    self.overflow += 1;
                    return None;
                }
                let buckets =
                    if kind.is_hist() { Some(Box::new([0u64; BUCKETS])) } else { None };
                self.slots.push(Slot { name, labels, kind, count: 0, sum: 0, max: 0, buckets });
                self.slots.last_mut()
            }
        }
    }

    fn record(&mut self, name: &'static str, labels: Labels, kind: Kind, v: u64) {
        if let Some(s) = self.slot_mut(name, labels, kind) {
            match kind {
                Kind::HistNs | Kind::HistUnits => {
                    s.count += 1;
                    s.sum = s.sum.saturating_add(v);
                    s.max = s.max.max(v);
                    if let Some(b) = s.buckets.as_deref_mut() {
                        b[bucket_of(v)] += 1;
                    }
                }
                Kind::Counter => {
                    s.count += 1;
                    s.sum = s.sum.saturating_add(v);
                    s.max = s.max.max(v);
                }
                Kind::Gauge => {
                    s.count += 1;
                    s.sum = v;
                    s.max = s.max.max(v);
                }
            }
        }
    }
}

thread_local! {
    static REG: RefCell<Registry> = RefCell::new(Registry::new());
}

/// Record a duration in nanoseconds into a latency histogram.
#[inline]
pub fn observe_ns(name: &'static str, labels: Labels, ns: u64) {
    if !enabled() {
        return;
    }
    REG.with(|r| r.borrow_mut().record(name, labels, Kind::HistNs, ns));
}

/// Record a unit-less magnitude (queue depth, in-flight count) into a
/// histogram.
#[inline]
pub fn observe(name: &'static str, labels: Labels, v: u64) {
    if !enabled() {
        return;
    }
    REG.with(|r| r.borrow_mut().record(name, labels, Kind::HistUnits, v));
}

/// Bump a monotonic counter by `n`.
#[inline]
pub fn add(name: &'static str, labels: Labels, n: u64) {
    if !enabled() {
        return;
    }
    REG.with(|r| r.borrow_mut().record(name, labels, Kind::Counter, n));
}

/// Set a gauge to `v` (merged across threads/ranks by maximum).
#[inline]
pub fn gauge_set(name: &'static str, labels: Labels, v: u64) {
    if !enabled() {
        return;
    }
    REG.with(|r| r.borrow_mut().record(name, labels, Kind::Gauge, v));
}

/// RAII latency sample: records `elapsed` into the named histogram on
/// drop. [`timer`] returns `None` (no clock read) when metrics are off.
pub struct Timer {
    t0: Instant,
    name: &'static str,
    labels: Labels,
}

impl Drop for Timer {
    fn drop(&mut self) {
        observe_ns(self.name, self.labels, self.t0.elapsed().as_nanos() as u64);
    }
}

/// Start a latency sample; `None` when metrics are disabled.
#[inline]
pub fn timer(name: &'static str, labels: Labels) -> Option<Timer> {
    if !enabled() {
        return None;
    }
    Some(Timer { t0: Instant::now(), name, labels })
}

// ---------------------------------------------------------------------------
// Merged (owned) side: what rank 0 accumulates and the exports read.
// ---------------------------------------------------------------------------

/// One merged metric, with owned keys (post-gather).
#[derive(Debug, Clone)]
pub struct OwnedMetric {
    pub name: String,
    /// Only the used pairs.
    pub labels: Vec<(String, String)>,
    pub kind: Kind,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    /// `BUCKETS` entries for histogram kinds, empty otherwise.
    pub buckets: Vec<u64>,
}

impl OwnedMetric {
    fn key_eq(&self, other: &OwnedMetric) -> bool {
        self.name == other.name && self.labels == other.labels
    }

    /// Merge `other` into `self` (same key): elementwise bucket addition,
    /// so the merge is associative and commutative.
    fn absorb(&mut self, other: &OwnedMetric) {
        match self.kind {
            Kind::Gauge => {
                self.sum = self.sum.max(other.sum);
                self.count += other.count;
                self.max = self.max.max(other.max);
            }
            _ => {
                self.count += other.count;
                self.sum = self.sum.saturating_add(other.sum);
                self.max = self.max.max(other.max);
                for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
                    *a += b;
                }
            }
        }
    }

    /// Smallest bucket upper bound covering quantile `q` (0..=1) of the
    /// recorded distribution; the top bucket reports the exact max.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 || self.buckets.is_empty() {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if b == BUCKETS - 1 { self.max } else { bucket_upper(b) };
            }
        }
        self.max
    }

    /// Rendered label selector, `{a="x",b="y"}` or empty.
    fn selector(&self, extra: Option<(&str, String)>) -> String {
        let mut parts: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{v}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }
}

/// The process-wide merged table (rank 0 side of [`rank_flush`]).
static WORLD: Mutex<Vec<OwnedMetric>> = Mutex::new(Vec::new());

fn merge_into(table: &mut Vec<OwnedMetric>, m: OwnedMetric) {
    match table.iter_mut().find(|t| t.key_eq(&m)) {
        Some(t) => t.absorb(&m),
        None => table.push(m),
    }
}

/// When latched, [`reset_world`] is a no-op: benches accumulate their
/// whole configuration matrix into one exported table instead of keeping
/// only the last measured world.
static HOLD_WORLD: AtomicBool = AtomicBool::new(false);

/// Latch (or release) world-table accumulation across runs — see
/// [`reset_world`]. Benches set this once before their matrix.
pub fn set_hold_world(on: bool) {
    HOLD_WORLD.store(on, Ordering::Relaxed);
}

/// Drop everything merged so far (driver calls this at the start of each
/// run so `--json`/`--metrics-out` describe exactly one world). A no-op
/// while [`set_hold_world`] is latched.
pub fn reset_world() {
    if HOLD_WORLD.load(Ordering::Relaxed) {
        return;
    }
    WORLD.lock().unwrap().clear();
}

/// Discard this thread's registry without flushing.
pub fn clear_local() {
    REG.with(|r| {
        let mut r = r.borrow_mut();
        r.slots.clear();
        r.overflow = 0;
    });
}

fn snapshot_registry(r: &Registry) -> Vec<OwnedMetric> {
    r.slots
        .iter()
        .map(|s| OwnedMetric {
            name: s.name.to_string(),
            labels: s
                .labels
                .iter()
                .filter(|(k, _)| !k.is_empty())
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            kind: s.kind,
            count: s.count,
            sum: s.sum,
            max: s.max,
            buckets: match s.buckets.as_deref() {
                Some(b) => b.to_vec(),
                None => Vec::new(),
            },
        })
        .collect()
}

fn snapshot_local() -> Vec<OwnedMetric> {
    REG.with(|r| snapshot_registry(&r.borrow()))
}

// Wire format (all u64 little-endian, strings length-prefixed):
//   n_metrics, then per metric:
//     kind, count, sum, max, name, n_labels, (lname, lvalue)*,
//     n_nonzero_buckets, (index, count)*
fn put_u64(wire: &mut Vec<u8>, v: u64) {
    wire.extend_from_slice(&v.to_le_bytes());
}

fn put_str(wire: &mut Vec<u8>, s: &str) {
    put_u64(wire, s.len() as u64);
    wire.extend_from_slice(s.as_bytes());
}

fn get_u64(wire: &[u8], at: &mut usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&wire[*at..*at + 8]);
    *at += 8;
    u64::from_le_bytes(b)
}

fn get_str(wire: &[u8], at: &mut usize) -> String {
    let len = get_u64(wire, at) as usize;
    let s = String::from_utf8_lossy(&wire[*at..*at + len]).into_owned();
    *at += len;
    s
}

fn encode(metrics: &[OwnedMetric]) -> Vec<u8> {
    let mut wire = Vec::new();
    put_u64(&mut wire, metrics.len() as u64);
    for m in metrics {
        put_u64(&mut wire, m.kind as u64);
        put_u64(&mut wire, m.count);
        put_u64(&mut wire, m.sum);
        put_u64(&mut wire, m.max);
        put_str(&mut wire, &m.name);
        put_u64(&mut wire, m.labels.len() as u64);
        for (k, v) in &m.labels {
            put_str(&mut wire, k);
            put_str(&mut wire, v);
        }
        let nnz = m.buckets.iter().filter(|&&c| c != 0).count();
        put_u64(&mut wire, nnz as u64);
        for (i, &c) in m.buckets.iter().enumerate() {
            if c != 0 {
                put_u64(&mut wire, i as u64);
                put_u64(&mut wire, c);
            }
        }
    }
    wire
}

fn decode(wire: &[u8]) -> Vec<OwnedMetric> {
    let mut at = 0usize;
    let n = get_u64(wire, &mut at) as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let kind = Kind::from_u64(get_u64(wire, &mut at));
        let count = get_u64(wire, &mut at);
        let sum = get_u64(wire, &mut at);
        let max = get_u64(wire, &mut at);
        let name = get_str(wire, &mut at);
        let nl = get_u64(wire, &mut at) as usize;
        let mut labels = Vec::with_capacity(nl);
        for _ in 0..nl {
            let k = get_str(wire, &mut at);
            let v = get_str(wire, &mut at);
            labels.push((k, v));
        }
        let nnz = get_u64(wire, &mut at) as usize;
        let mut buckets = if kind.is_hist() { vec![0u64; BUCKETS] } else { Vec::new() };
        for _ in 0..nnz {
            let i = get_u64(wire, &mut at) as usize;
            let c = get_u64(wire, &mut at);
            if i < buckets.len() {
                buckets[i] = c;
            }
        }
        out.push(OwnedMetric { name, labels, kind, count, sum, max, buckets });
    }
    out
}

/// End-of-world collective reduction: every rank drains its registry;
/// ranks `1..n` ship theirs to rank 0, which merges everything into the
/// process table. Same protocol and poisoned-world behaviour as the trace
/// gather — a poisoned world skips the collective and discards locally.
pub(crate) fn rank_flush(comm: &Comm) {
    // Consult the world-creation snapshot, not the live global: every rank
    // must make the same participate/skip decision or the gather deadlocks
    // (a concurrent test could flip the global mid-teardown).
    if !comm.ctl().metrics_on() {
        clear_local();
        return;
    }
    if comm.ctl().poisoned() {
        clear_local();
        return;
    }
    let mine = snapshot_local();
    clear_local();
    if comm.rank() == 0 {
        let mut table = WORLD.lock().unwrap();
        for m in mine {
            merge_into(&mut table, m);
        }
        for p in 1..comm.size() {
            for m in decode(&comm.recv_bytes(p, TAG_METRICS)) {
                merge_into(&mut table, m);
            }
        }
    } else {
        comm.send_bytes(0, TAG_METRICS, encode(&mine));
    }
}

/// Test/bench hook: merge this thread's registry straight into the
/// process table without a world (what `rank_flush` does on rank 0).
pub fn flush_local_to_world() {
    let mine = snapshot_local();
    clear_local();
    let mut table = WORLD.lock().unwrap();
    for m in mine {
        merge_into(&mut table, m);
    }
}

/// Quantile summary of one merged histogram (or total of one counter),
/// the unit of the `metrics` block in `RunReport` / `--json` rows.
/// Durations are in **seconds**.
#[derive(Debug, Clone)]
pub struct MetricSummary {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub kind: Kind,
    pub count: u64,
    /// p50/p90/p99/max; seconds for `HistNs`, raw units otherwise. For
    /// counters/gauges only `max` is meaningful (the total / the value).
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

fn scale(kind: Kind, v: u64) -> f64 {
    match kind {
        Kind::HistNs => v as f64 * 1e-9,
        _ => v as f64,
    }
}

/// Summaries of everything merged so far, sorted by (name, labels) for
/// deterministic output.
pub fn summaries() -> Vec<MetricSummary> {
    summaries_of(WORLD.lock().unwrap().clone())
}

/// [`summaries`] over an explicit table (unit tests and custom merges).
pub fn summaries_of(mut table: Vec<OwnedMetric>) -> Vec<MetricSummary> {
    table.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
    table
        .iter()
        .map(|m| MetricSummary {
            name: m.name.clone(),
            labels: m.labels.clone(),
            kind: m.kind,
            count: m.count,
            p50: scale(m.kind, m.quantile(0.50)),
            p90: scale(m.kind, m.quantile(0.90)),
            p99: scale(m.kind, m.quantile(0.99)),
            max: scale(
                m.kind,
                if m.kind == Kind::Counter { m.sum } else { m.max },
            ),
        })
        .collect()
}

/// Render everything merged so far as Prometheus text exposition format.
/// Histogram buckets are cumulative with `le` in the histogram's native
/// unit (seconds for `*_seconds`); empty buckets are skipped (the format
/// allows sparse `le` ladders), `+Inf`, `_sum` and `_count` always
/// present.
pub fn render_prometheus() -> String {
    render_prometheus_of(WORLD.lock().unwrap().clone())
}

/// [`render_prometheus`] over an explicit table.
pub fn render_prometheus_of(mut table: Vec<OwnedMetric>) -> String {
    table.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
    let mut out = String::new();
    let mut typed: Vec<String> = Vec::new();
    for m in &table {
        let (typ, unit_scale) = match m.kind {
            Kind::HistNs => ("histogram", 1e-9),
            Kind::HistUnits => ("histogram", 1.0),
            Kind::Counter => ("counter", 1.0),
            Kind::Gauge => ("gauge", 1.0),
        };
        if !typed.contains(&m.name) {
            out.push_str(&format!("# TYPE {} {}\n", m.name, typ));
            typed.push(m.name.clone());
        }
        match m.kind {
            Kind::Counter => {
                out.push_str(&format!("{}{} {}\n", m.name, m.selector(None), m.sum));
            }
            Kind::Gauge => {
                out.push_str(&format!("{}{} {}\n", m.name, m.selector(None), m.sum));
            }
            _ => {
                let mut cum = 0u64;
                for (b, &c) in m.buckets.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    cum += c;
                    let le = bucket_upper(b) as f64 * unit_scale;
                    out.push_str(&format!(
                        "{}_bucket{} {}\n",
                        m.name,
                        m.selector(Some(("le", format!("{le:.9e}")))),
                        cum
                    ));
                }
                out.push_str(&format!(
                    "{}_bucket{} {}\n",
                    m.name,
                    m.selector(Some(("le", "+Inf".to_string()))),
                    m.count
                ));
                out.push_str(&format!(
                    "{}_sum{} {:.9e}\n",
                    m.name,
                    m.selector(None),
                    m.sum as f64 * unit_scale
                ));
                out.push_str(&format!("{}_count{} {}\n", m.name, m.selector(None), m.count));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Flight recorder
// ---------------------------------------------------------------------------

static FLIGHT_EPOCH: OnceLock<Instant> = OnceLock::new();
static FLIGHT: Mutex<Vec<(i32, &'static str, u64)>> = Mutex::new(Vec::new());
static FLIGHT_DUMP: Mutex<Option<FlightSnapshot>> = Mutex::new(None);

fn flight_epoch() -> Instant {
    *FLIGHT_EPOCH.get_or_init(Instant::now)
}

/// Should span sites feed the flight recorder? True whenever anything
/// that could consume a failure dump is live: metrics on, tracing on, or
/// a chaos world active.
#[inline]
pub fn flight_active() -> bool {
    enabled() || crate::trace::enabled() || crate::simmpi::fault::chaos_active()
}

/// Note a span entry in the process-wide flight ring (rank `-1` when the
/// calling thread is not a bound world rank). Bounded: the oldest note is
/// overwritten once the ring holds [`FLIGHT_CAP`] entries.
pub fn flight_note(rank: i32, label: &'static str) {
    let t = flight_epoch().elapsed().as_nanos() as u64;
    let mut ring = FLIGHT.lock().unwrap();
    if ring.len() >= FLIGHT_CAP {
        ring.remove(0);
    }
    ring.push((rank, label, t));
}

/// What the failure JSON embeds: the recent-span ring plus a metrics
/// snapshot of the capturing thread at the moment of death.
#[derive(Debug, Clone)]
pub struct FlightSnapshot {
    /// Rank whose failure triggered the capture.
    pub rank: usize,
    /// Failure context string (same text as the `WorldError`).
    pub context: String,
    /// `(rank, span_label, t_ns)` notes, oldest first.
    pub notes: Vec<(i32, String, u64)>,
    /// Local metric summaries of the capturing thread (may be empty when
    /// the capture runs off-thread, e.g. from the panic joiner).
    pub metrics: Vec<MetricSummary>,
}

/// Capture the flight ring into the process dump slot — first writer
/// wins, matching the first-recorded-failure semantics of `WorldCtl`.
/// Called on the watchdog abort path and when a rank's panic is recorded.
pub fn flight_capture(rank: usize, context: &str) {
    let notes: Vec<(i32, String, u64)> = FLIGHT
        .lock()
        .unwrap()
        .iter()
        .map(|(r, l, t)| (*r, (*l).to_string(), *t))
        .collect();
    let local = snapshot_local();
    let metrics = local
        .iter()
        .map(|m| MetricSummary {
            name: m.name.clone(),
            labels: m.labels.clone(),
            kind: m.kind,
            count: m.count,
            p50: scale(m.kind, m.quantile(0.50)),
            p90: scale(m.kind, m.quantile(0.90)),
            p99: scale(m.kind, m.quantile(0.99)),
            max: scale(m.kind, if m.kind == Kind::Counter { m.sum } else { m.max }),
        })
        .collect();
    let mut slot = FLIGHT_DUMP.lock().unwrap();
    if slot.is_none() {
        *slot = Some(FlightSnapshot { rank, context: context.to_string(), notes, metrics });
    }
}

/// Drain the captured flight snapshot (consumed by the failure JSON).
pub fn take_flight() -> Option<FlightSnapshot> {
    FLIGHT_DUMP.lock().unwrap().take()
}

/// Clear the flight ring and any captured dump (start of a fresh run).
pub fn reset_flight() {
    FLIGHT.lock().unwrap().clear();
    *FLIGHT_DUMP.lock().unwrap() = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn owned(vals: &[u64]) -> OwnedMetric {
        let mut m = OwnedMetric {
            name: "t".into(),
            labels: Vec::new(),
            kind: Kind::HistUnits,
            count: 0,
            sum: 0,
            max: 0,
            buckets: vec![0; BUCKETS],
        };
        for &v in vals {
            m.count += 1;
            m.sum += v;
            m.max = m.max.max(v);
            m.buckets[bucket_of(v)] += 1;
        }
        m
    }

    #[test]
    fn bucket_index_is_monotone_and_bounded() {
        let mut last = 0usize;
        for v in 0..100_000u64 {
            let b = bucket_of(v);
            assert!(b >= last, "bucket_of not monotone at {v}");
            assert!(b < BUCKETS);
            last = b;
        }
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        // Every value is ≤ its bucket's upper bound, and > the previous
        // bucket's.
        for v in [0u64, 1, 7, 8, 9, 255, 256, 1_000_000, 123_456_789] {
            let b = bucket_of(v);
            assert!(v <= bucket_upper(b), "{v} > upper({b})");
            if b > 0 {
                assert!(v > bucket_upper(b - 1), "{v} <= upper({})", b - 1);
            }
        }
    }

    #[test]
    fn quantiles_track_scripted_workload() {
        // 1..=1000 uniformly: p50 ≈ 500, p99 ≈ 990, with ≤12.5% bucket
        // resolution error above, never below the true quantile.
        let vals: Vec<u64> = (1..=1000).collect();
        let m = owned(&vals);
        for (q, truth) in [(0.50, 500u64), (0.90, 900), (0.99, 990)] {
            let got = m.quantile(q);
            assert!(got >= truth, "q{q}: {got} < {truth}");
            assert!(
                (got as f64) <= truth as f64 * 1.13 + 1.0,
                "q{q}: {got} too far above {truth}"
            );
        }
        assert_eq!(m.quantile(1.0), 1000);
        assert_eq!(m.max, 1000);
    }

    #[test]
    fn merge_is_associative() {
        let a = owned(&[1, 5, 9, 1000]);
        let b = owned(&[2, 6, 10_000]);
        let c = owned(&[3, 70, 7_777_777]);
        let mut ab_c = a.clone();
        ab_c.absorb(&b);
        ab_c.absorb(&c);
        let mut bc = b.clone();
        bc.absorb(&c);
        let mut a_bc = a.clone();
        a_bc.absorb(&bc);
        assert_eq!(ab_c.count, a_bc.count);
        assert_eq!(ab_c.sum, a_bc.sum);
        assert_eq!(ab_c.max, a_bc.max);
        assert_eq!(ab_c.buckets, a_bc.buckets);
    }

    #[test]
    fn wire_roundtrip_preserves_everything() {
        let mut c = OwnedMetric {
            name: "retries_total".into(),
            labels: vec![("op".into(), "send".into())],
            kind: Kind::Counter,
            count: 3,
            sum: 7,
            max: 4,
            buckets: Vec::new(),
        };
        let h = owned(&[1, 2, 3, 500, 1_000_000]);
        let wire = encode(&[h.clone(), c.clone()]);
        let back = decode(&wire);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].buckets, h.buckets);
        assert_eq!(back[0].count, h.count);
        assert_eq!(back[1].sum, c.sum);
        assert_eq!(back[1].labels, c.labels);
        c.absorb(&back[1]);
        assert_eq!(c.sum, 14);
    }

    // The registry → summaries → Prometheus path over a local registry:
    // the process-global table and enable flag are shared with every other
    // test in this binary (driver tests run worlds with metrics on), so
    // unit tests stay off them; the global plumbing is exercised by
    // `tests/metrics_observability.rs` in its own process.
    #[test]
    fn registry_records_and_renders() {
        let mut reg = Registry::new();
        reg.record("unit_test_lat", label1("site", "here"), Kind::HistNs, 1_000);
        reg.record("unit_test_lat", label1("site", "here"), Kind::HistNs, 2_000);
        reg.record("unit_test_total", NO_LABELS, Kind::Counter, 5);
        reg.record("unit_test_gauge", NO_LABELS, Kind::Gauge, 42);
        let table = snapshot_registry(&reg);
        assert_eq!(table.len(), 3);
        let s = summaries_of(table.clone());
        let lat = s.iter().find(|m| m.name == "unit_test_lat").unwrap();
        assert_eq!(lat.count, 2);
        assert_eq!(lat.labels, vec![("site".to_string(), "here".to_string())]);
        assert!(lat.p50 >= 1e-6 && lat.p50 < 2e-6, "p50 {}", lat.p50);
        let total = s.iter().find(|m| m.name == "unit_test_total").unwrap();
        assert_eq!(total.max, 5.0);
        let text = render_prometheus_of(table);
        assert!(text.contains("# TYPE unit_test_lat histogram"));
        assert!(text.contains("unit_test_lat_bucket{site=\"here\",le=\"+Inf\"} 2"));
        assert!(text.contains("unit_test_lat_count{site=\"here\"} 2"));
        assert!(text.contains("unit_test_total 5"));
        assert!(text.contains("unit_test_gauge 42"));
    }

    #[test]
    fn registry_merges_across_snapshots() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        for v in [10u64, 200, 3_000] {
            a.record("m", NO_LABELS, Kind::HistUnits, v);
            b.record("m", NO_LABELS, Kind::HistUnits, v * 7);
        }
        let mut table = Vec::new();
        for m in snapshot_registry(&a).into_iter().chain(snapshot_registry(&b)) {
            merge_into(&mut table, m);
        }
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].count, 6);
        assert_eq!(table[0].max, 21_000);
    }
}
