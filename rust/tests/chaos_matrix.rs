//! Chaos matrix: deterministic fault injection swept across the full
//! (method × transport × exec) space, plus watchdog deadlock regressions.
//!
//! The invariant under test is the robustness contract of the simmpi
//! stack: **every run either completes bitwise-correct, or terminates
//! within the watchdog deadline with a structured
//! [`WorldError::RankFailed`] — never a hang, never silent corruption.**
//!
//! * benign schedules (delays, reorders, stalls, transient drops within
//!   the retry budget) must leave results bitwise-identical to the clean
//!   run of the same configuration;
//! * lethal schedules (delivery failure past the retry budget, scripted
//!   rank panics at a trace-span boundary) must surface as
//!   `RunError::Rank` naming the guilty rank, fast;
//! * classic deadlocks (mismatched-tag exchange, never-drained window
//!   epoch) must fail within the watchdog deadline with diagnostics
//!   naming the blocked operation, peer and tag.

use std::time::{Duration, Instant};

use a2wfft::coordinator::{run_config_checked, Knob, RunConfig, RunError, Transport};
use a2wfft::pfft::{ExecMode, Kind, RedistMethod};
use a2wfft::simmpi::{Window, World, WorldError, WorldOptions};

/// Every (method, transport, exec) combination the planner accepts, over
/// a small-but-3D mesh on 4 ranks (2 ranks/node so the hierarchical
/// method genuinely aggregates).
fn matrix() -> Vec<(RedistMethod, Transport, ExecMode)> {
    vec![
        (RedistMethod::Alltoallw, Transport::Mailbox, ExecMode::Blocking),
        (RedistMethod::Alltoallw, Transport::Mailbox, ExecMode::Pipelined { depth: 2 }),
        (RedistMethod::Alltoallw, Transport::Window, ExecMode::Blocking),
        (RedistMethod::Alltoallw, Transport::Window, ExecMode::Pipelined { depth: 2 }),
        (RedistMethod::Traditional, Transport::Mailbox, ExecMode::Blocking),
        (RedistMethod::Hierarchical, Transport::Mailbox, ExecMode::Blocking),
        (RedistMethod::Hierarchical, Transport::Window, ExecMode::Blocking),
    ]
}

fn cfg_for(
    method: RedistMethod,
    transport: Transport,
    exec: ExecMode,
    schedule: Option<&str>,
    seed: u64,
) -> RunConfig {
    RunConfig {
        global: vec![12, 10, 8],
        ranks: 4,
        ranks_per_node: 2,
        kind: Kind::C2c,
        method: Knob::Fixed(method),
        exec: Knob::Fixed(exec),
        transport: Knob::Fixed(transport),
        inner: 1,
        outer: 1,
        fault_schedule: schedule.map(String::from),
        fault_seed: seed,
        // Generous for CI boxes, tiny next to "hangs forever".
        watchdog_ms: Some(20_000),
        ..Default::default()
    }
}

fn label(method: RedistMethod, transport: Transport, exec: ExecMode) -> String {
    format!("{method:?}/{transport:?}/{exec:?}")
}

#[test]
fn benign_faults_complete_bitwise_clean_across_matrix() {
    // Delays, a reorder, a recv stall and a transient delivery failure
    // (retried well inside the retry budget): every configuration must
    // complete with a clean roundtrip and the exact wire-byte counts of
    // its fault-free twin.
    let schedules = [
        "delay@0:us=50; reorder@1:nth=1; stall@2:op=recv:nth=2:us=40",
        "drop@1:nth=1:count=2; delay@3:op=complete:nth=1:us=30",
    ];
    for (method, transport, exec) in matrix() {
        let tag = label(method, transport, exec);
        let clean = run_config_checked(&cfg_for(method, transport, exec, None, 0), 2)
            .unwrap_or_else(|e| panic!("{tag}: clean run failed: {e}"));
        assert!(clean.max_err < 1e-10, "{tag}: clean roundtrip err {:.3e}", clean.max_err);
        for schedule in schedules {
            let chaotic =
                run_config_checked(&cfg_for(method, transport, exec, Some(schedule), 42), 2)
                    .unwrap_or_else(|e| panic!("{tag} + {schedule:?}: failed: {e}"));
            assert!(
                chaotic.max_err < 1e-10,
                "{tag} + {schedule:?}: roundtrip err {:.3e}",
                chaotic.max_err
            );
            // Same wire traffic as the clean twin: faults may delay and
            // reorder, but never change what moves.
            assert_eq!(chaotic.bytes, clean.bytes, "{tag} + {schedule:?}: wire bytes diverge");
            assert_eq!(
                chaotic.one_copy_bytes, clean.one_copy_bytes,
                "{tag} + {schedule:?}: one-copy bytes diverge"
            );
        }
    }
}

#[test]
fn exhausted_delivery_retries_fail_structured_across_matrix() {
    // A delivery fault that outlives the retry budget must surface as a
    // structured rank failure naming the exhausted retries — on every
    // configuration, without hanging (the watchdog is armed as backstop).
    let schedule = "drop@0:nth=1:count=99";
    for (method, transport, exec) in matrix() {
        let tag = label(method, transport, exec);
        let started = Instant::now();
        let err = run_config_checked(&cfg_for(method, transport, exec, Some(schedule), 7), 2)
            .err()
            .unwrap_or_else(|| panic!("{tag}: lethal drop unexpectedly completed"));
        let elapsed = started.elapsed();
        match &err {
            RunError::Rank(WorldError::RankFailed { rank, context }) => {
                assert_eq!(*rank, 0, "{tag}: wrong guilty rank: {context}");
                assert!(
                    context.contains("retries exhausted"),
                    "{tag}: context missing retry diagnosis: {context}"
                );
            }
            other => panic!("{tag}: expected a rank failure, got {other}"),
        }
        assert!(elapsed < Duration::from_secs(60), "{tag}: failure took {elapsed:?}");
    }
}

#[test]
fn scripted_panic_at_span_boundary_fails_structured() {
    // A scripted rank death at the first entry of the `exchange` span:
    // the error names the rank, the span and the seed, and the run never
    // hangs waiting for the dead rank.
    for transport in [Transport::Mailbox, Transport::Window] {
        let cfg = cfg_for(
            RedistMethod::Alltoallw,
            transport,
            ExecMode::Blocking,
            Some("panic@1:span=exchange:at=1"),
            3,
        );
        let err = run_config_checked(&cfg, 2)
            .err()
            .unwrap_or_else(|| panic!("{transport:?}: scripted panic unexpectedly completed"));
        match &err {
            RunError::Rank(WorldError::RankFailed { rank, context }) => {
                assert_eq!(*rank, 1, "{transport:?}: wrong guilty rank: {context}");
                assert!(
                    context.contains("span 'exchange'"),
                    "{transport:?}: context missing span: {context}"
                );
            }
            other => panic!("{transport:?}: expected a rank failure, got {other}"),
        }
    }
}

#[test]
fn chaos_is_deterministic_same_seed_same_failure() {
    // The whole point of seeded schedules: the identical (schedule, seed)
    // pair reproduces the identical structured failure.
    let run = || {
        run_config_checked(
            &cfg_for(
                RedistMethod::Alltoallw,
                Transport::Mailbox,
                ExecMode::Blocking,
                Some("drop@2:nth=3:count=99"),
                11,
            ),
            2,
        )
    };
    let (a, b) = (run(), run());
    match (&a, &b) {
        (
            Err(RunError::Rank(WorldError::RankFailed { rank: ra, context: ca })),
            Err(RunError::Rank(WorldError::RankFailed { rank: rb, context: cb })),
        ) => {
            assert_eq!(ra, rb, "guilty rank not reproducible");
            assert_eq!(ca, cb, "failure context not reproducible");
        }
        other => panic!("expected two identical rank failures, got {other:?}"),
    }
}

#[test]
fn mismatched_tag_exchange_fails_within_watchdog_naming_peer_and_tag() {
    // The classic deadlock: both ranks block in a recv whose matching
    // send never happened. The watchdog converts the hang into a
    // structured failure whose diagnostic names the blocked receive
    // (peer, tag) and summarizes the unmatched inbox.
    let started = Instant::now();
    let res = World::run_opts(2, WorldOptions::default().with_watchdog_ms(500), |comm| {
        if comm.rank() == 0 {
            comm.send_bytes(1, 0x1, vec![1, 2, 3]);
            // Rank 1 sent tag 0x3; waiting on 0x2 deadlocks.
            comm.recv_bytes(1, 0x2)
        } else {
            comm.send_bytes(0, 0x3, vec![4, 5]);
            comm.recv_bytes(0, 0x5)
        }
    });
    let elapsed = started.elapsed();
    let err = res.err().expect("mismatched-tag exchange must fail");
    let WorldError::RankFailed { context, .. } = &err;
    assert!(context.contains("recv(from=rank"), "missing blocked recv: {context}");
    assert!(context.contains("unmatched inbox"), "missing inbox summary: {context}");
    assert!(context.contains("watchdog"), "missing watchdog attribution: {context}");
    assert!(elapsed < Duration::from_secs(30), "watchdog too slow: {elapsed:?}");
}

#[test]
fn undrained_window_epoch_fails_within_watchdog_naming_owner() {
    // A window exposure epoch whose origin never completes: rank 0 posts
    // for rank 1 and waits, rank 1 walks away. The watchdog names the
    // owner and the epoch completion count instead of hanging forever.
    let started = Instant::now();
    let res = World::run_opts(2, WorldOptions::default().with_watchdog_ms(500), |comm| {
        let mut win = Window::allocate(&comm, 64);
        if comm.rank() == 0 {
            win.post(&[1]);
            win.wait(); // rank 1 never starts/completes: deadlock
        }
    });
    let elapsed = started.elapsed();
    let err = res.err().expect("undrained window epoch must fail");
    let WorldError::RankFailed { rank, context } = &err;
    assert_eq!(*rank, 0, "the waiting owner is the failing rank: {context}");
    assert!(context.contains("window wait on rank 0"), "missing owner: {context}");
    assert!(context.contains("access epochs completed"), "missing epoch count: {context}");
    assert!(elapsed < Duration::from_secs(30), "watchdog too slow: {elapsed:?}");
}

#[test]
fn clean_world_under_watchdog_never_false_triggers() {
    // An armed watchdog over a healthy world is free: a full matrix pass
    // with no schedule completes exactly as without it (covered for
    // correctness in benign_faults_complete_bitwise_clean_across_matrix;
    // here the point is a tight deadline over a run that is slow relative
    // to POLL still never false-fires, because progress resets nothing —
    // the deadline only expires while truly blocked).
    let res = World::run_opts(4, WorldOptions::default().with_watchdog_ms(10_000), |comm| {
        // A chain of dependent exchanges with deliberate think time well
        // past the poll quantum.
        let me = comm.rank();
        let next = (me + 1) % comm.size();
        let prev = (me + comm.size() - 1) % comm.size();
        for round in 0..3u32 {
            std::thread::sleep(Duration::from_millis(50));
            comm.send_bytes(next, round, vec![me as u8]);
            let got = comm.recv_bytes(prev, round);
            assert_eq!(got, vec![prev as u8]);
        }
        comm.barrier();
        me
    });
    let ranks = res.expect("healthy world must not trip the watchdog");
    assert_eq!(ranks, vec![0, 1, 2, 3]);
}
