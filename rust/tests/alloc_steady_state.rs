//! Steady-state allocation accounting for the compiled transfer-plan
//! engine: after warmup, executions of compiled plans must perform **zero
//! heap allocations** on the intra-rank path (fused copies + arena-recycled
//! staging).
//!
//! Uses a counting global allocator with a *thread-local* counter, so each
//! measurement only observes its own thread (the cargo test harness runs
//! tests concurrently; a process-global counter would be polluted).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use a2wfft::redistribute::{HierarchicalPlan, PipelinedRedistPlan, RedistPlan};
use a2wfft::simmpi::datatype::{Datatype, TransferPlan};
use a2wfft::simmpi::{Transport, World};

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers to the system allocator; the counter is a plain Cell of a
// primitive with no destructor, safe to touch from the allocator hook.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

#[test]
fn fused_transfer_plan_execute_never_allocates() {
    let send = Datatype::subarray(&[8, 10, 6], &[4, 5, 6], &[2, 3, 0], 8).unwrap();
    let recv = Datatype::subarray(&[5, 9, 8], &[4, 5, 6], &[1, 2, 1], 8).unwrap();
    let plan = TransferPlan::compile(&send, &recv).unwrap();
    let src = vec![0xABu8; send.extent()];
    let mut dst = vec![0u8; recv.extent()];
    plan.execute(&src, &mut dst); // warmup (nothing to warm, but symmetric)
    let n0 = allocs_on_this_thread();
    for _ in 0..100 {
        plan.execute(&src, &mut dst);
    }
    let delta = allocs_on_this_thread() - n0;
    assert_eq!(delta, 0, "fused execute allocated {delta} times in 100 runs");
}

#[test]
fn steady_state_pipelined_redistribution_never_allocates() {
    // Single-rank world: every byte of the redistribution moves through the
    // intra-rank engine (fused self-exchange, arena-staged local capture,
    // preallocated chunk scratch). After two warmup round-trips the arenas
    // are primed and further executions must not touch the heap.
    World::run(1, |comm| {
        let sizes = [4usize, 6, 8];
        let mut plan = PipelinedRedistPlan::new(&comm, 8, &sizes, 0, &sizes, 1, 4, 2);
        assert!(plan.is_pipelined(), "expected a chunked plan (pipe axis 2)");
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| x as f64 * 1.5).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..2 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "roundtrip broken");
        let n0 = allocs_on_this_thread();
        for _ in 0..5 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        let msg = format!("steady-state pipelined executions allocated {delta} times in 5 trips");
        assert_eq!(delta, 0, "{msg}");
        assert_eq!(a, back, "roundtrip broken after steady-state runs");
    });
}

#[test]
fn steady_state_blocking_redist_plan_single_rank_never_allocates() {
    // The blocking compiled RedistPlan at one rank is a pure fused
    // TransferPlan execution (plus one wire-tag fetch): zero allocations
    // from the very first execute.
    World::run(1, |comm| {
        let sizes = [6usize, 5, 4];
        let plan = RedistPlan::new(&comm, 8, &sizes, 2, &sizes, 0);
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| x as f64 - 7.0).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        plan.execute(&a, &mut b);
        let n0 = allocs_on_this_thread();
        for _ in 0..10 {
            plan.execute(&a, &mut b);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(delta, 0, "blocking fused executions allocated {delta} times");
    });
}

#[test]
fn steady_state_pooled_batched_fft_never_allocates() {
    // The lane-batched + multithreaded serial engine: after one warmup
    // pass per (axis, direction) — planner cache primed, per-worker
    // panels/scratch grown, pool sinks preallocated — steady-state
    // transforms allocate nothing, on the rank thread *and* on every pool
    // worker (each asserts its own thread-local counter via a broadcast
    // probe). Lengths cover pow2 (64), mixed-radix (6) and Bluestein (67).
    use std::sync::atomic::{AtomicU64, Ordering};

    use a2wfft::fft::{Complex, Direction, EngineCfg, NativeFft, SerialFft};

    let shape = [6usize, 67, 64];
    let total: usize = shape.iter().product();
    let mut data: Vec<Complex<f64>> =
        (0..total).map(|k| Complex::new((k as f64 * 0.61).sin(), (k as f64 * 0.23).cos())).collect();
    let mut eng = NativeFft::<f64>::with_cfg(EngineCfg::new(8, 4));
    let nthreads = eng.pool().threads();
    assert_eq!(nthreads, 4, "pool must carry the configured thread count");
    // Warmup: every axis, both directions.
    for dir in [Direction::Forward, Direction::Backward] {
        for axis in 0..3 {
            eng.c2c(&mut data, &shape, axis, dir);
        }
    }
    // Snapshot every thread's allocation counter (a broadcast runs the
    // probe once per pool thread, worker id = slot index).
    fn probe(eng: &NativeFft<f64>, into: &[AtomicU64]) {
        eng.pool().broadcast(&|wid, _| {
            into[wid].store(allocs_on_this_thread(), Ordering::SeqCst);
        });
    }
    let before: Vec<AtomicU64> = (0..nthreads).map(|_| AtomicU64::new(0)).collect();
    let after: Vec<AtomicU64> = (0..nthreads).map(|_| AtomicU64::new(0)).collect();
    probe(&eng, &before);
    for _ in 0..3 {
        for axis in 0..3 {
            eng.c2c(&mut data, &shape, axis, Direction::Forward);
            eng.c2c(&mut data, &shape, axis, Direction::Backward);
        }
    }
    probe(&eng, &after);
    for wid in 0..nthreads {
        let delta = after[wid].load(Ordering::SeqCst) - before[wid].load(Ordering::SeqCst);
        assert_eq!(
            delta, 0,
            "thread {wid}: steady-state pooled transforms allocated {delta} times"
        );
    }
}

#[test]
fn steady_state_window_transport_multi_rank_never_allocates() {
    // The one-copy window transport has *no payload buffers at all*: after
    // the exposure-hub map warms its capacity, multi-rank executions are
    // allocation-free on every rank thread — stronger than the mailbox
    // path, whose per-message payload Vecs the arenas merely recycle. The
    // counting allocator is thread-local, so each rank asserts its own
    // steady state independently.
    World::run(2, |comm| {
        let me = comm.rank();
        let global = [6usize, 8, 4];
        let m = comm.size();
        let sizes_a = [global[0], a2wfft::decomp::decompose(global[1], m, me).0, global[2]];
        let sizes_b = [a2wfft::decomp::decompose(global[0], m, me).0, global[1], global[2]];
        let plan =
            RedistPlan::with_transport(&comm, 8, &sizes_a, 0, &sizes_b, 1, Transport::Window);
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| (me * 77 + x) as f64).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..3 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "rank {me}: roundtrip broken");
        comm.barrier();
        let n0 = allocs_on_this_thread();
        for _ in 0..10 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(
            delta, 0,
            "rank {me}: steady-state window executions allocated {delta} times in 10 trips"
        );
        assert_eq!(a, back, "rank {me}: roundtrip broken after steady-state runs");
    });
}

/// Shared body of the hierarchical steady-state tests: 4 ranks in 2-rank
/// nodes, so every execute exercises all three phases (intra gather, one
/// inter-node aggregate message, intra scatter). Returns this rank's
/// allocation delta over 10 steady-state round-trips.
fn hier_steady_state(transport: Transport) -> Vec<u64> {
    World::run(4, move |comm| {
        let me = comm.rank();
        let global = [8usize, 8, 6];
        let m = comm.size();
        let sizes_a = [global[0], a2wfft::decomp::decompose(global[1], m, me).0, global[2]];
        let sizes_b = [a2wfft::decomp::decompose(global[0], m, me).0, global[1], global[2]];
        let mut plan = HierarchicalPlan::with_transport(
            &comm, 8, &sizes_a, 0, &sizes_b, 1, transport, 2,
        );
        assert_eq!(plan.node_map().node_count(), 2);
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| (me * 53 + x) as f64).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..3 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "rank {me}: roundtrip broken");
        comm.barrier();
        let n0 = allocs_on_this_thread();
        for _ in 0..10 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(a, back, "rank {me}: roundtrip broken after steady-state runs");
        delta
    })
}

#[test]
fn steady_state_hierarchical_window_never_allocates() {
    // Node aggregation adds two compiled intra phases and plan-owned
    // aggregate scratch on top of the one-copy wire; after warmup primes
    // the offset tables and hub capacity, the whole gather → exchange →
    // scatter cycle must stay off the heap on every rank — leaders and
    // members alike.
    for (rank, delta) in hier_steady_state(Transport::Window).into_iter().enumerate() {
        assert_eq!(
            delta, 0,
            "rank {rank}: steady-state hierarchical window executions allocated {delta} times"
        );
    }
}

#[test]
fn steady_state_hierarchical_mailbox_plan_machinery_never_allocates() {
    // On the mailbox wire the *simulated transport itself* allocates per
    // message (each payload Vec travels through a fresh tag bucket), as it
    // does for every mailbox method — so the invariant splits: non-leader
    // ranks touch no wire and must be exactly allocation-free, while
    // leaders may only pay the wire's constant per-message bookkeeping
    // (the aggregates themselves recycle through the arena — a growing
    // aggregate would blow well past this bound).
    let deltas = hier_steady_state(Transport::Mailbox);
    // 10 round-trips × 2 directions × 1 remote node = 20 messages/leader.
    let messages = 20u64;
    for (rank, delta) in deltas.into_iter().enumerate() {
        let leader = rank % 2 == 0; // ranks_per_node = 2: ranks 0 and 2 lead
        if leader {
            assert!(
                delta <= 4 * messages,
                "rank {rank}: {delta} allocations for {messages} messages — \
                 aggregate buffers are not recycling"
            );
        } else {
            assert_eq!(
                delta, 0,
                "rank {rank}: non-leader steady-state executions allocated {delta} times"
            );
        }
    }
}

#[test]
fn steady_state_window_pipelined_never_allocates() {
    // The pipelined engine on the window transport: persistent
    // sub-exchanges expose/pull raw spans (no payload staging), chunk
    // scratch is preallocated, and the in-flight queues keep their
    // capacity — so steady-state round-trips are allocation-free on every
    // rank thread.
    World::run(2, |comm| {
        let me = comm.rank();
        let global = [6usize, 8, 10];
        let m = comm.size();
        let sizes_a = [global[0], a2wfft::decomp::decompose(global[1], m, me).0, global[2]];
        let sizes_b = [a2wfft::decomp::decompose(global[0], m, me).0, global[1], global[2]];
        let mut plan = PipelinedRedistPlan::with_transport(
            &comm,
            8,
            &sizes_a,
            0,
            &sizes_b,
            1,
            4,
            2,
            Transport::Window,
        );
        assert!(plan.is_pipelined());
        assert_eq!(plan.transport(), Transport::Window);
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| (me * 31 + x) as f64).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..3 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "rank {me}: roundtrip broken");
        comm.barrier();
        let n0 = allocs_on_this_thread();
        for _ in 0..5 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(
            delta, 0,
            "rank {me}: steady-state window pipelined executions allocated {delta} times"
        );
        assert_eq!(a, back, "rank {me}: roundtrip broken after steady-state runs");
    });
}
