//! Integration tests of the autotuning planner and its persistent wisdom:
//!
//! * wisdom JSON roundtrip — write → load → identical signature match;
//! * tuner determinism under the injected [`FakeMeasurer`] — scripted
//!   timings produce a predictable winner, across world sizes and both
//!   dtypes;
//! * property: [`PfftPlan::tuned`] output is **bitwise equal** to the same
//!   plan built explicitly with the winning configuration;
//! * the wisdom lifecycle end-to-end — search persists, a repeat problem
//!   recalls without measuring, `force` re-measures.

use std::path::PathBuf;

use a2wfft::fft::{Complex, NativeFft, Real};
use a2wfft::pfft::{Kind, PfftPlan};
use a2wfft::simmpi::World;
use a2wfft::tune::{tune_plan, Budget, FakeMeasurer, Signature, TuneSpace, Wisdom};

/// Unique temp path per test (tests run concurrently in one process).
fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("a2wfft_{tag}_{}.json", std::process::id()))
}

#[test]
fn wisdom_file_roundtrip_identical_signature_match() {
    let path = temp_path("wisdom_roundtrip");
    let sig = Signature::new::<f64>(&[32, 24, 16], 4, Kind::R2c);
    let space = TuneSpace::new(&[32, 24, 16], 4, Budget::Normal);
    let (cands, _) = space.candidates();
    let mut w = Wisdom::default();
    w.record(&sig, &cands[3], 1.5e-3, "normal");
    // A second, different signature coexists.
    let sig32 = Signature::new::<f32>(&[32, 24, 16], 4, Kind::R2c);
    w.record(&sig32, &cands[0], 2.5e-3, "normal");
    w.store(&path).unwrap();

    let back = Wisdom::load(&path).unwrap();
    assert_eq!(back.entries.len(), 2);
    let hit = back.lookup(&sig.key()).expect("stored signature must match after reload");
    assert_eq!(hit.candidate().unwrap(), cands[3]);
    assert_eq!(hit.seconds, 1.5e-3);
    assert_eq!(hit.budget, "normal");
    let hit32 = back.lookup(&sig32.key()).unwrap();
    assert_eq!(hit32.candidate().unwrap(), cands[0]);
    // Unknown signatures still miss.
    assert!(back.lookup("r2c/f64/g1x1x1/r99").is_none());
    std::fs::remove_file(&path).ok();
}

fn winner_label_under_fake<T: Real>(global: &[usize], ranks: usize, kind: Kind) -> (String, String) {
    // Script the *last* enumerated candidate to be the fastest: if the
    // tuner is deterministic, it must surface exactly that one, on every
    // world size and precision.
    let space = TuneSpace::new(global, ranks, Budget::Tiny);
    let (cands, _) = space.candidates();
    let target = cands.last().unwrap().label();
    let fake = FakeMeasurer::new(1.0).with(&target, 1e-6);
    let global_v = global.to_vec();
    let target_c = target.clone();
    let reports = World::run(ranks, move |comm| {
        let report =
            tune_plan::<T>(&comm, &global_v, kind, Budget::Tiny, 1, None, false, &fake);
        // Every rank agrees on the full ranking, not just the winner.
        let order: Vec<String> =
            report.entries.iter().map(|e| e.candidate.label()).collect();
        assert_eq!(order.first().unwrap(), &target_c, "winner mismatch on a rank");
        order.join(";")
    });
    // All ranks produced the identical ranking string.
    let first = reports[0].clone();
    for r in &reports {
        assert_eq!(*r, first, "ranks disagree on the ranking");
    }
    (target, first)
}

#[test]
fn tuner_is_deterministic_under_fake_measurer() {
    for ranks in [1usize, 2, 4] {
        let (t64, rank64) = winner_label_under_fake::<f64>(&[16, 12, 10], ranks, Kind::R2c);
        // Re-running the identical search reproduces the identical ranking.
        let (t64b, rank64b) = winner_label_under_fake::<f64>(&[16, 12, 10], ranks, Kind::R2c);
        assert_eq!(t64, t64b);
        assert_eq!(rank64, rank64b);
        // Both precisions: same space, same scripted winner.
        let (t32, _) = winner_label_under_fake::<f32>(&[16, 12, 10], ranks, Kind::C2c);
        assert_eq!(t64, t32, "candidate space must not depend on dtype");
    }
}

#[test]
fn tuned_plan_is_bitwise_equal_to_explicit_winner() {
    // Script winners of several characters (pipelined/window included)
    // and check the tuned plan's spectra against a plan built explicitly
    // from the winning configuration — bitwise, per rank.
    let global = vec![12, 10, 8];
    let ranks = 4;
    let space = TuneSpace::new(&global, ranks, Budget::Tiny);
    let (cands, _) = space.candidates();
    // One candidate of each flavor that exists in the tiny space.
    let picks: Vec<String> = {
        let mut picks = Vec::new();
        if let Some(c) = cands.iter().find(|c| c.transport.name() == "window") {
            picks.push(c.label());
        }
        if let Some(c) = cands.iter().find(|c| c.exec.depth() > 0) {
            picks.push(c.label());
        }
        if let Some(c) = cands.iter().find(|c| c.method.name() == "traditional") {
            picks.push(c.label());
        }
        picks
    };
    assert!(picks.len() >= 3, "tiny space unexpectedly narrow: {picks:?}");
    for target in picks {
        let fake = FakeMeasurer::new(1.0).with(&target, 1e-9);
        let global_c = global.clone();
        World::run(ranks, move |comm| {
            let mut tuned = PfftPlan::<f64>::tuned_with(
                &comm,
                &global_c,
                Kind::C2c,
                Budget::Tiny,
                None,
                &fake,
            );
            // The tuned plan IS the scripted winner...
            let dims = tuned.dims().to_vec();
            let mut explicit = PfftPlan::<f64>::with_transport(
                &comm,
                &global_c,
                &dims,
                Kind::C2c,
                tuned.method(),
                tuned.exec_mode(),
                tuned.transport(),
            );
            // ...and transforms bitwise-identically to the explicit build.
            let me = comm.rank();
            let ilen = tuned.input_len();
            let input: Vec<Complex<f64>> = (0..ilen)
                .map(|k| {
                    Complex::from_f64(
                        (k as f64 * 0.37 + me as f64).sin(),
                        (k as f64 * 0.11 - me as f64).cos(),
                    )
                })
                .collect();
            let mut engine = NativeFft::<f64>::new();
            let mut spec_tuned = vec![Complex::<f64>::ZERO; tuned.output_len()];
            let mut spec_explicit = vec![Complex::<f64>::ZERO; explicit.output_len()];
            tuned.forward(&mut engine, &input, &mut spec_tuned);
            explicit.forward(&mut engine, &input, &mut spec_explicit);
            assert_eq!(
                spec_tuned, spec_explicit,
                "rank {me}: tuned plan diverges from its explicit twin"
            );
            let mut back_tuned = vec![Complex::<f64>::ZERO; ilen];
            let mut back_explicit = vec![Complex::<f64>::ZERO; ilen];
            tuned.backward(&mut engine, &spec_tuned, &mut back_tuned);
            explicit.backward(&mut engine, &spec_explicit, &mut back_explicit);
            assert_eq!(back_tuned, back_explicit, "rank {me}: backward diverges");
        });
    }
}

#[test]
fn wisdom_is_keyed_by_node_grouping() {
    // A winner measured under a 2-ranks-per-node grouping persists under
    // the /rpn2 signature and must not satisfy the flat problem (the
    // hierarchical candidate's plans differ between the two machines).
    let path = temp_path("wisdom_topology");
    std::fs::remove_file(&path).ok();
    let global = vec![16, 12, 10];
    let ranks = 2;
    let fake = FakeMeasurer::new(1.0);
    let global_1 = global.clone();
    let path_1 = path.clone();
    let grouped = World::run(ranks, move |comm| {
        tune_plan::<f64>(
            &comm,
            &global_1,
            Kind::R2c,
            Budget::Tiny,
            2,
            Some(path_1.as_path()),
            false,
            &fake,
        )
    })
    .remove(0);
    assert!(!grouped.from_wisdom);
    assert!(grouped.signature.key().ends_with("/rpn2"), "{}", grouped.signature.key());
    let w = Wisdom::load(&path).unwrap();
    assert!(w.lookup(&grouped.signature.key()).is_some());
    let flat = Signature::new::<f64>(&global, ranks, Kind::R2c);
    assert!(w.lookup(&flat.key()).is_none(), "grouped wisdom leaked into the flat signature");
    std::fs::remove_file(&path).ok();
}

#[test]
fn garbled_wisdom_degrades_to_fresh_measurement() {
    // Corruption of every flavor — truncated JSON, non-JSON bytes, wrong
    // schema version, entries of the wrong shape — must degrade to a
    // plain measured search (with a stderr warning), never an error, and
    // the subsequent persist must leave the file valid again.
    let global = vec![16, 12, 10];
    let ranks = 2;
    let space = TuneSpace::new(&global, ranks, Budget::Tiny);
    let (cands, _) = space.candidates();
    let target = cands.last().unwrap().label();
    for (tag, garbage) in [
        ("truncated", r#"{"wisdom": 1, "entries": [{"signature": "r2c"#),
        ("not_json", "\x00\x01\x02 this is not json at all"),
        ("wrong_version", r#"{"wisdom": 999, "entries": []}"#),
        ("bad_entry_shape", r#"{"wisdom": 1, "entries": [{"signature": 42}]}"#),
        ("empty_file", ""),
    ] {
        let path = temp_path(&format!("wisdom_garbled_{tag}"));
        std::fs::write(&path, garbage).unwrap();
        let fake = FakeMeasurer::new(1.0).with(&target, 1e-6);
        let global_c = global.clone();
        let path_c = path.clone();
        let report = World::run(ranks, move |comm| {
            tune_plan::<f64>(
                &comm,
                &global_c,
                Kind::R2c,
                Budget::Tiny,
                1,
                Some(path_c.as_path()),
                false,
                &fake,
            )
        })
        .remove(0);
        assert!(!report.from_wisdom, "{tag}: corrupt wisdom must not satisfy a lookup");
        assert_eq!(report.winner().candidate.label(), target, "{tag}");
        assert!(report.persisted, "{tag}: the search must rewrite the corrupt file");
        let w = Wisdom::load(&path).unwrap_or_else(|e| panic!("{tag}: rewritten file unreadable: {e}"));
        let sig = Signature::new::<f64>(&global, ranks, Kind::R2c);
        assert!(w.lookup(&sig.key()).is_some(), "{tag}: rewritten wisdom misses");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn wisdom_lifecycle_search_recall_force() {
    let path = temp_path("wisdom_lifecycle");
    std::fs::remove_file(&path).ok();
    let global = vec![16, 12, 10];
    let ranks = 2;
    let space = TuneSpace::new(&global, ranks, Budget::Tiny);
    let (cands, _) = space.candidates();
    let target = cands.last().unwrap().label();

    // 1. First tune: measures, persists the winner.
    let global_1 = global.clone();
    let path_1 = path.clone();
    let fake_1 = FakeMeasurer::new(1.0).with(&target, 1e-6);
    let first = World::run(ranks, move |comm| {
        tune_plan::<f64>(
            &comm,
            &global_1,
            Kind::R2c,
            Budget::Tiny,
            1,
            Some(path_1.as_path()),
            false,
            &fake_1,
        )
    })
    .remove(0);
    assert!(!first.from_wisdom);
    assert!(first.persisted, "search must report a successful wisdom write");
    assert_eq!(first.winner().candidate.label(), target);
    assert!(path.exists(), "search must persist wisdom");

    // 2. Same signature again: resolved from wisdom, no measurement —
    //    the fake scripts a *different* winner now, which must be
    //    ignored because nothing is measured.
    let other = cands.first().unwrap().label();
    let global_2 = global.clone();
    let path_2 = path.clone();
    let fake_2 = FakeMeasurer::new(1.0).with(&other, 1e-9);
    let second = World::run(ranks, move |comm| {
        tune_plan::<f64>(
            &comm,
            &global_2,
            Kind::R2c,
            Budget::Tiny,
            1,
            Some(path_2.as_path()),
            false,
            &fake_2,
        )
    })
    .remove(0);
    assert!(second.from_wisdom, "repeat problem must resolve from wisdom");
    assert!(!second.persisted, "a recall writes nothing");
    assert_eq!(second.winner().candidate.label(), target);
    assert_eq!(second.entries.len(), 1);

    // 3. force: re-measures (the new scripted winner surfaces) and
    //    replaces the wisdom entry.
    let global_3 = global.clone();
    let path_3 = path.clone();
    let fake_3 = FakeMeasurer::new(1.0).with(&other, 1e-9);
    let third = World::run(ranks, move |comm| {
        tune_plan::<f64>(
            &comm,
            &global_3,
            Kind::R2c,
            Budget::Tiny,
            1,
            Some(path_3.as_path()),
            true,
            &fake_3,
        )
    })
    .remove(0);
    assert!(!third.from_wisdom);
    assert_eq!(third.winner().candidate.label(), other);
    let w = Wisdom::load(&path).unwrap();
    let sig = Signature::new::<f64>(&global, ranks, Kind::R2c);
    assert_eq!(w.lookup(&sig.key()).unwrap().candidate().unwrap().label(), other);
    // A different signature (other world size) still misses.
    let sig4 = Signature::new::<f64>(&global, 4, Kind::R2c);
    assert!(w.lookup(&sig4.key()).is_none());
    std::fs::remove_file(&path).ok();
}
