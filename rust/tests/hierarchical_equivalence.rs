//! Hierarchical-redistribution equivalence: the node-aware two-phase
//! exchange must be **bitwise identical** to the flat subarray alltoallw
//! at every layer — raw redistribution plans and full distributed
//! transforms — over random shapes, grids, node groupings, transports and
//! dtypes (deterministic xorshift sweeps; the offline crate set has no
//! proptest). Topology changes how bytes travel, never what they are.

use a2wfft::fft::{Complex, NativeFft, Real};
use a2wfft::pfft::{ExecMode, Kind, PfftPlan, RedistMethod};
use a2wfft::redistribute::{HierarchicalPlan, RedistPlan};
use a2wfft::simmpi::{as_bytes, dims_create, Transport, World};

/// Small deterministic PRNG (xorshift64*).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

#[test]
fn prop_hier_redist_plan_bitwise_equals_flat() {
    // Raw redistribution layer: HierarchicalPlan vs the flat RedistPlan,
    // random shapes/axes/world sizes, every node grouping from fully
    // distributed to fully shared (including ragged last nodes).
    let mut rng = Rng::new(43);
    for case in 0..12 {
        let d = rng.range(2, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let nprocs = rng.range(2, 5);
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let rpn = rng.range(1, 4);
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = a2wfft::decomp::decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = a2wfft::decomp::decompose(global_c[axis_a], m, me).0;
            let mut lr = Rng::new(seed ^ (me as u64 + 1));
            let a: Vec<f64> =
                (0..sizes_a.iter().product::<usize>()).map(|_| lr.f64()).collect();
            let flat = RedistPlan::new(&comm, 8, &sizes_a, axis_a, &sizes_b, axis_b);
            let mut hier =
                HierarchicalPlan::new(&comm, 8, &sizes_a, axis_a, &sizes_b, axis_b, rpn);
            let mut b_flat = vec![0.0f64; flat.elems_b()];
            flat.execute(&a, &mut b_flat);
            let mut b_hier = vec![0.0f64; hier.elems_b()];
            hier.execute(&a, &mut b_hier);
            assert_eq!(
                as_bytes(&b_flat),
                as_bytes(&b_hier),
                "case {case} rank {me} rpn {rpn}: hierarchical disagrees with flat"
            );
            let mut back = vec![0.0f64; hier.elems_a()];
            hier.execute_back(&b_hier, &mut back);
            assert_eq!(
                as_bytes(&a),
                as_bytes(&back),
                "case {case} rank {me} rpn {rpn}: roundtrip"
            );
        });
    }
}

/// One transform case at precision `T`: the hierarchical method on both
/// transports must produce spectra and roundtrips bitwise identical to the
/// flat alltoallw reference (and therefore to each other).
fn transform_case<T: Real>(
    global: Vec<usize>,
    ranks: usize,
    grid_ndims: usize,
    kind: Kind,
    ranks_per_node: usize,
    seed: u64,
    case: usize,
) {
    World::run(ranks, move |comm| {
        let me = comm.rank();
        let dims = dims_create(comm.size(), grid_ndims);
        let mk = |method: RedistMethod, transport: Transport| {
            PfftPlan::<T>::with_topology(
                &comm,
                &global,
                &dims,
                kind,
                method,
                ExecMode::Blocking,
                transport,
                ranks_per_node,
            )
        };
        let mut flat = mk(RedistMethod::Alltoallw, Transport::Mailbox);
        let mut hier_mail = mk(RedistMethod::Hierarchical, Transport::Mailbox);
        let mut hier_win = mk(RedistMethod::Hierarchical, Transport::Window);
        let mut engine = NativeFft::<T>::new();
        let ilen = flat.input_len();
        let olen = flat.output_len();
        let mut lr = Rng::new(seed ^ (me as u64).wrapping_mul(0x5851F42D4C957F2D));
        match kind {
            Kind::C2c => {
                let input: Vec<Complex<T>> =
                    (0..ilen).map(|_| Complex::from_f64(lr.f64(), lr.f64())).collect();
                let mut spec_flat = vec![Complex::<T>::ZERO; olen];
                let mut spec_mail = vec![Complex::<T>::ZERO; olen];
                let mut spec_win = vec![Complex::<T>::ZERO; olen];
                flat.forward(&mut engine, &input, &mut spec_flat);
                hier_mail.forward(&mut engine, &input, &mut spec_mail);
                hier_win.forward(&mut engine, &input, &mut spec_win);
                assert_eq!(
                    as_bytes(&spec_flat),
                    as_bytes(&spec_mail),
                    "case {case} rank {me} rpn {ranks_per_node} [{}]: hier/mailbox spectra",
                    T::NAME
                );
                assert_eq!(
                    as_bytes(&spec_flat),
                    as_bytes(&spec_win),
                    "case {case} rank {me} rpn {ranks_per_node} [{}]: hier/window spectra",
                    T::NAME
                );
                let mut back_flat = vec![Complex::<T>::ZERO; ilen];
                let mut back_hier = vec![Complex::<T>::ZERO; ilen];
                flat.backward(&mut engine, &spec_flat, &mut back_flat);
                hier_mail.backward(&mut engine, &spec_mail, &mut back_hier);
                assert_eq!(
                    as_bytes(&back_flat),
                    as_bytes(&back_hier),
                    "case {case} rank {me}: roundtrips differ"
                );
            }
            Kind::R2c => {
                let input: Vec<T> = (0..ilen).map(|_| T::from_f64(lr.f64())).collect();
                let mut spec_flat = vec![Complex::<T>::ZERO; olen];
                let mut spec_mail = vec![Complex::<T>::ZERO; olen];
                let mut spec_win = vec![Complex::<T>::ZERO; olen];
                flat.forward_r2c(&mut engine, &input, &mut spec_flat);
                hier_mail.forward_r2c(&mut engine, &input, &mut spec_mail);
                hier_win.forward_r2c(&mut engine, &input, &mut spec_win);
                assert_eq!(
                    as_bytes(&spec_flat),
                    as_bytes(&spec_mail),
                    "case {case} rank {me} rpn {ranks_per_node} [{}]: r2c hier/mailbox",
                    T::NAME
                );
                assert_eq!(
                    as_bytes(&spec_flat),
                    as_bytes(&spec_win),
                    "case {case} rank {me} rpn {ranks_per_node} [{}]: r2c hier/window",
                    T::NAME
                );
                let mut back_flat = vec![T::ZERO; ilen];
                let mut back_hier = vec![T::ZERO; ilen];
                flat.backward_c2r(&mut engine, &spec_flat, &mut back_flat);
                hier_win.backward_c2r(&mut engine, &spec_win, &mut back_hier);
                assert_eq!(
                    as_bytes(&back_flat),
                    as_bytes(&back_hier),
                    "case {case} rank {me}: c2r roundtrips differ"
                );
            }
        }
    });
}

#[test]
fn prop_transform_spectra_bitwise_equal_across_topologies() {
    // Random shapes / ranks / grids / kinds, node groupings sweeping
    // 1 (degenerate: one node per rank) through ranks (one node total),
    // including non-dividing groupings (ragged last node), both dtypes.
    let mut rng = Rng::new(44);
    for case in 0..10 {
        let d = rng.range(3, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(4, 11)).collect();
        let ranks = rng.range(2, 5);
        let grid_ndims = rng.range(1, (d - 1).min(2));
        let kind = if rng.below(2) == 0 { Kind::C2c } else { Kind::R2c };
        let rpn = rng.range(1, 4);
        let seed = rng.next_u64();
        if rng.below(2) == 0 {
            transform_case::<f64>(global, ranks, grid_ndims, kind, rpn, seed, case);
        } else {
            transform_case::<f32>(global, ranks, grid_ndims, kind, rpn, seed, case);
        }
    }
}

#[test]
fn hierarchical_matches_traditional_baseline() {
    // Cross-method triangle at a fixed pencil case: the node-aware
    // two-phase exchange must agree bitwise with the traditional
    // remap+alltoallv baseline — two maximally different data paths.
    World::run(4, |comm| {
        let me = comm.rank();
        let global = vec![8usize, 12, 6];
        let dims = dims_create(comm.size(), 2);
        let mut hier = PfftPlan::<f64>::with_topology(
            &comm,
            &global,
            &dims,
            Kind::C2c,
            RedistMethod::Hierarchical,
            ExecMode::Blocking,
            Transport::Window,
            2,
        );
        let mut trad = PfftPlan::<f64>::with_dims(
            &comm,
            &global,
            &dims,
            Kind::C2c,
            RedistMethod::Traditional,
        );
        let mut engine = NativeFft::<f64>::new();
        let input: Vec<Complex<f64>> = (0..hier.input_len())
            .map(|k| Complex::new((me * 1000 + k) as f64 * 0.25, (k as f64 * 0.5).sin()))
            .collect();
        let mut spec_hier = vec![Complex::<f64>::ZERO; hier.output_len()];
        let mut spec_trad = vec![Complex::<f64>::ZERO; trad.output_len()];
        hier.forward(&mut engine, &input, &mut spec_hier);
        trad.forward(&mut engine, &input, &mut spec_trad);
        assert_eq!(
            as_bytes(&spec_hier),
            as_bytes(&spec_trad),
            "rank {me}: hierarchical != traditional baseline"
        );
    });
}

#[test]
fn hierarchical_message_count_is_node_pairs() {
    // The headline invariant at the plan layer: one combined inter-node
    // message per remote node, independent of how many ranks share each
    // node — against P-1 peer messages for the flat exchange.
    for (ranks, rpn, nodes) in [(4usize, 2usize, 2usize), (4, 4, 1), (6, 2, 3), (5, 2, 3)] {
        World::run(ranks, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = vec![12usize, 8, 6];
            let mut sizes_b = vec![12usize, 8, 6];
            sizes_a[1] = a2wfft::decomp::decompose(8, m, me).0;
            sizes_b[0] = a2wfft::decomp::decompose(12, m, me).0;
            let hier = HierarchicalPlan::new(&comm, 8, &sizes_a, 0, &sizes_b, 1, rpn);
            assert_eq!(hier.node_map().node_count(), nodes, "ranks {ranks} rpn {rpn}");
            assert_eq!(
                hier.inter_messages_per_exchange(),
                nodes - 1,
                "ranks {ranks} rpn {rpn}: must ship one message per remote node"
            );
            if nodes == 1 {
                assert_eq!(hier.inter_bytes_per_exchange(), 0, "one node: nothing crosses");
            }
        });
    }
}
