//! Metrics-registry acceptance: the always-on observability stack end to
//! end.
//!
//! Covers the tentpole invariants: deterministic cross-rank reduction at
//! every world size, quantile correctness of the merged log-bucketed
//! histograms, zero steady-state allocations with metrics **on** (the
//! PR-2 invariant extended to the registry), the flight recorder landing
//! in the structured `failure` JSON of a chaos run, and well-formed
//! Prometheus text exposition output.
//!
//! The registry enable flag, the merged world table and the flight ring
//! are process-global, so every test here serializes on one mutex and
//! resets the globals on entry (this binary runs in its own process — the
//! lib tests deliberately stay off these globals). Uses the same
//! thread-local counting allocator as `alloc_steady_state.rs`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Mutex;

use a2wfft::coordinator::benchkit::{failure_json, report_json};
use a2wfft::coordinator::trend::JsonValue;
use a2wfft::coordinator::{run_config, run_config_checked, RunConfig};
use a2wfft::metrics::{self, NO_LABELS};
use a2wfft::redistribute::PipelinedRedistPlan;
use a2wfft::simmpi::World;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers to the system allocator; the counter is a plain Cell of a
// primitive with no destructor, safe to touch from the allocator hook.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Serializes every test that touches the process-global metrics state.
static GUARD: Mutex<()> = Mutex::new(());

/// Enter the guarded region with clean global state.
fn guarded() -> std::sync::MutexGuard<'static, ()> {
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    metrics::set_enabled(false);
    metrics::set_hold_world(false);
    metrics::reset_world();
    metrics::reset_flight();
    metrics::clear_local();
    g
}

#[test]
fn gather_reduces_deterministically_across_world_sizes() {
    let _g = guarded();
    for n in [1usize, 2, 4] {
        for repeat in 0..2 {
            metrics::reset_world();
            metrics::set_enabled(true);
            World::run(n, |comm| {
                // Rank r records r+1 scripted depths and bumps a counter
                // by r+1: the merged table must reduce to exact, repeat-
                // independent totals at every world size.
                for k in 0..=comm.rank() {
                    metrics::observe("test_depth", NO_LABELS, (comm.rank() * 10 + k) as u64);
                }
                metrics::add("test_ops_total", NO_LABELS, 1 + comm.rank() as u64);
            });
            metrics::set_enabled(false);
            let s = metrics::summaries();
            let depth = s.iter().find(|m| m.name == "test_depth").unwrap();
            let records: u64 = (1..=n as u64).sum();
            assert_eq!(depth.count, records, "world {n} repeat {repeat}");
            assert_eq!(depth.max, (11 * (n - 1)) as f64, "world {n} repeat {repeat}");
            let ops = s.iter().find(|m| m.name == "test_ops_total").unwrap();
            let expect: u64 = (0..n as u64).map(|r| 1 + r).sum();
            assert_eq!(ops.max, expect as f64, "counter total, world {n} repeat {repeat}");
        }
    }
}

#[test]
fn merged_quantiles_match_scripted_distribution() {
    let _g = guarded();
    metrics::reset_world();
    metrics::set_enabled(true);
    // Four ranks record the same 250 values (4, 8, ..., 1000): the merge
    // is elementwise bucket addition, so the merged distribution is the
    // per-rank one with 4x the mass and identical quantiles.
    World::run(4, |_comm| {
        for v in 1..=250u64 {
            metrics::observe("scripted_units", NO_LABELS, v * 4);
        }
    });
    metrics::set_enabled(false);
    let s = metrics::summaries();
    let m = s.iter().find(|m| m.name == "scripted_units").unwrap();
    assert_eq!(m.count, 1000);
    assert_eq!(m.max, 1000.0);
    // Bucket resolution is 8 linear sub-buckets per octave: the reported
    // quantile is a bucket upper bound, at or at most ~12.5% above truth.
    for (q, truth) in [(m.p50, 500.0f64), (m.p90, 900.0), (m.p99, 990.0)] {
        assert!(q >= truth, "quantile {q} below truth {truth}");
        assert!(q <= truth * 1.13 + 1.0, "quantile {q} too far above truth {truth}");
    }
}

#[test]
fn metrics_on_steady_state_is_allocation_free() {
    let _g = guarded();
    metrics::set_enabled(true);
    // Same workload as the alloc_steady_state pipelined test, but with the
    // registry recording every exchange/copy/depth sample: after warmup
    // primes the slot table (and the flight ring is at capacity, as in any
    // run older than a few milliseconds), executions must never touch the
    // heap.
    World::run(1, |comm| {
        for _ in 0..metrics::FLIGHT_CAP {
            metrics::flight_note(0, "prefill");
        }
        let sizes = [4usize, 6, 8];
        let mut plan = PipelinedRedistPlan::new(&comm, 8, &sizes, 0, &sizes, 1, 4, 2);
        assert!(plan.is_pipelined());
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| x as f64 * 1.5).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..2 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "roundtrip broken");
        let n0 = allocs_on_this_thread();
        for _ in 0..5 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(delta, 0, "metrics-on executions allocated {delta} times in 5 trips");
    });
    metrics::set_enabled(false);
    // The run recorded real boundary metrics while staying heap-silent.
    let s = metrics::summaries();
    let depth = s.iter().find(|m| m.name == "a2wfft_chunk_inflight_depth").unwrap();
    assert!(depth.count > 0, "no in-flight depth samples recorded");
}

#[test]
fn chaos_failure_json_carries_the_flight_recorder() {
    let _g = guarded();
    // A scripted rank death mid-exchange: the driver returns the
    // structured failure and the flight recorder must land in its JSON.
    let cfg = RunConfig {
        global: vec![16, 12, 10],
        ranks: 4,
        inner: 1,
        outer: 1,
        fault_schedule: Some("panic@1:span=exchange:at=1".into()),
        watchdog_ms: Some(10_000),
        ..Default::default()
    };
    let err = run_config_checked(&cfg, 2).unwrap_err();
    let json = failure_json("chaos", &cfg.global, 4, &err);
    let doc = JsonValue::parse(&json).expect("failure row is not valid JSON");
    let failure = doc.get("failure").expect("failure object missing");
    assert_eq!(failure.get("rank").and_then(|v| v.as_num()), Some(1.0));
    let flight = failure.get("flight").expect("failure JSON missing the flight recorder");
    assert_eq!(flight.get("rank").and_then(|v| v.as_num()), Some(1.0));
    assert!(flight.get("context").and_then(|v| v.as_str()).unwrap().contains("exchange"));
    let spans = flight.get("recent_spans").and_then(|v| v.as_arr()).unwrap();
    assert!(!spans.is_empty(), "flight ring empty at capture");
    assert!(
        spans.iter().any(|s| s.get("span").and_then(|v| v.as_str()) == Some("exchange")),
        "no exchange span among the recent notes"
    );
    for s in spans {
        assert!(s.get("rank").and_then(|v| v.as_num()).is_some());
        assert!(s.get("t_ns").and_then(|v| v.as_num()).is_some());
    }
    // The capture is drained: a second export has no flight section.
    assert!(metrics::take_flight().is_none());
}

#[test]
fn flight_ring_is_bounded_and_captures_once() {
    let _g = guarded();
    metrics::set_enabled(true);
    for _ in 0..metrics::FLIGHT_CAP + 50 {
        metrics::flight_note(3, "spin");
    }
    metrics::observe("flight_local_metric", NO_LABELS, 7);
    metrics::flight_capture(3, "first failure");
    metrics::flight_capture(0, "cascade");
    metrics::set_enabled(false);
    let snap = metrics::take_flight().unwrap();
    // First writer wins (the primary failure), the ring stays bounded,
    // and the capture carries the thread's local metric snapshot.
    assert_eq!((snap.rank, snap.context.as_str()), (3, "first failure"));
    assert_eq!(snap.notes.len(), metrics::FLIGHT_CAP);
    assert!(snap.metrics.iter().any(|m| m.name == "flight_local_metric"));
    assert!(metrics::take_flight().is_none(), "capture must drain exactly once");
    metrics::clear_local();
}

/// Minimal Prometheus text-format well-formedness check: `# TYPE` lines
/// declare a known type; every sample line is `series value` with a
/// parseable value; histogram bucket counts are cumulative and the
/// `+Inf` bucket equals `_count`.
fn validate_prometheus(text: &str) {
    use std::collections::BTreeMap;
    let mut last_cum: BTreeMap<String, u64> = BTreeMap::new();
    let mut inf: BTreeMap<String, u64> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            assert!(!it.next().unwrap().is_empty(), "unnamed TYPE line: {line}");
            let typ = it.next().expect("TYPE line without a type");
            assert!(
                matches!(typ, "histogram" | "counter" | "gauge"),
                "unknown metric type in: {line}"
            );
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("sample line without a value: {line}")
        });
        assert!(!series.is_empty(), "empty series in: {line}");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in: {line}"));
        assert!(v.is_finite() && v >= 0.0, "negative/non-finite sample: {line}");
        if let Some(rest) = series.split_once("_bucket{") {
            // Strip the le pair: the remaining selector identifies the
            // series the cumulative counts belong to.
            let (name, sel) = rest;
            let sel = sel.trim_end_matches('}');
            let ident: Vec<&str> =
                sel.split(',').filter(|p| !p.starts_with("le=")).collect();
            let key = format!("{name}{{{}}}", ident.join(","));
            let c = v as u64;
            if sel.contains("le=\"+Inf\"") {
                inf.insert(key.clone(), c);
            }
            let prev = last_cum.entry(key).or_insert(0);
            assert!(c >= *prev, "bucket counts not cumulative at: {line}");
            *prev = c;
        }
        if let Some((name_sel, _)) = series.split_once("_count") {
            // `_count` must equal the +Inf bucket of the same series.
            let key = format!("{}{}", name_sel, {
                let sel = series.split_once("_count").unwrap().1;
                if sel.is_empty() { "{}".to_string() } else { sel.to_string() }
            });
            if let Some(&i) = inf.get(&key) {
                assert_eq!(i, v as u64, "+Inf bucket != _count for {key}");
            }
        }
    }
}

#[test]
fn run_exports_are_well_formed() {
    let _g = guarded();
    // A plain driver run with the default metrics=on: the JSON row must
    // carry the summaries block and the Prometheus rendering must be
    // well-formed, with every core hot boundary represented.
    let cfg =
        RunConfig { global: vec![16, 12, 10], ranks: 4, inner: 1, outer: 1, ..Default::default() };
    let rep = run_config(&cfg, 2);
    assert!(rep.max_err < 1e-9);
    let s = metrics::summaries();
    for name in [
        "a2wfft_exchange_seconds",
        "a2wfft_fft_axis_seconds",
        "a2wfft_copy_seconds",
        "a2wfft_mailbox_queue_depth",
    ] {
        let m = s.iter().find(|m| m.name == name);
        assert!(m.is_some_and(|m| m.count > 0), "core boundary {name} not recorded");
    }
    // Quantiles are monotone (p50 <= p90 <= p99) on every histogram.
    for m in &s {
        assert!(m.p50 <= m.p90 + 1e-12 && m.p90 <= m.p99 + 1e-12, "{}: quantile order", m.name);
    }
    // The --json row embeds the same summaries.
    let row = JsonValue::parse(&report_json("m", &cfg.global, &[2, 2], 4, &rep)).unwrap();
    let block = row.get("metrics").and_then(|v| v.as_arr()).expect("metrics block missing");
    assert!(!block.is_empty());
    let exch = block
        .iter()
        .find(|m| m.get("name").and_then(|v| v.as_str()) == Some("a2wfft_exchange_seconds"))
        .expect("exchange histogram missing from the JSON block");
    for field in ["count", "p50", "p90", "p99", "max"] {
        assert!(exch.get(field).and_then(|v| v.as_num()).is_some(), "{field} missing");
    }
    assert!(exch.get("method").and_then(|v| v.as_str()).is_some(), "method label missing");
    // Prometheus text export.
    let text = metrics::render_prometheus();
    assert!(text.contains("# TYPE a2wfft_exchange_seconds histogram"));
    assert!(text.contains("a2wfft_exchange_seconds_bucket"));
    assert!(text.contains("le=\"+Inf\""));
    assert!(text.contains("a2wfft_exchange_seconds_sum"));
    assert!(text.contains("a2wfft_exchange_seconds_count"));
    validate_prometheus(&text);
}

#[test]
fn no_metrics_run_records_nothing() {
    let _g = guarded();
    let cfg = RunConfig {
        global: vec![16, 12, 10],
        ranks: 2,
        inner: 1,
        outer: 1,
        metrics: false,
        ..Default::default()
    };
    let rep = run_config(&cfg, 1);
    assert!(rep.max_err < 1e-9);
    assert!(metrics::summaries().is_empty(), "--no-metrics run left merged metrics behind");
    assert_eq!(metrics::render_prometheus(), "");
}
