//! Integration tests: the distributed FFT against a serial reference, for
//! every decomposition the paper exercises (slab, pencil, 3-D grid on 4-D
//! data — Appendices A and B), both transform kinds and both
//! redistribution methods.

use a2wfft::fft::{fft_axis, max_abs_diff, Complex64, Direction, NativeFft, Planner};
use a2wfft::pfft::{Kind, PfftPlan, RedistMethod};
use a2wfft::simmpi::World;

/// Deterministic global test field.
fn field(gidx: usize) -> Complex64 {
    let x = gidx as f64;
    Complex64::new((x * 0.37).sin() + 0.25 * (x * 0.11).cos(), (x * 0.23).cos() - 0.5)
}

/// Linear global index from a multi-index.
fn lin(global: &[usize], idx: &[usize]) -> usize {
    idx.iter().zip(global).fold(0, |acc, (&i, &n)| acc * n + i)
}

/// Fill this rank's window of the global complex array.
fn fill_local(global: &[usize], window: &[(usize, usize)]) -> Vec<Complex64> {
    let total: usize = window.iter().map(|&(_, l)| l).product();
    let d = global.len();
    (0..total)
        .map(|k| {
            let mut rem = k;
            let mut idx = vec![0usize; d];
            for a in (0..d).rev() {
                idx[a] = window[a].0 + rem % window[a].1;
                rem /= window[a].1;
            }
            field(lin(global, &idx))
        })
        .collect()
}

/// Serial full ND forward transform of the deterministic field.
fn serial_reference(global: &[usize], dir: Direction) -> Vec<Complex64> {
    let total: usize = global.iter().product();
    let mut data: Vec<Complex64> = (0..total).map(field).collect();
    let mut planner = Planner::new();
    let axes: Vec<usize> = (0..global.len()).collect();
    for &a in axes.iter().rev() {
        fft_axis(&mut planner, &mut data, global, a, dir);
    }
    data
}

/// Extract a window from a global array.
fn window_of(global: &[usize], data: &[Complex64], window: &[(usize, usize)]) -> Vec<Complex64> {
    let d = global.len();
    let total: usize = window.iter().map(|&(_, l)| l).product();
    (0..total)
        .map(|k| {
            let mut rem = k;
            let mut idx = vec![0usize; d];
            for a in (0..d).rev() {
                idx[a] = window[a].0 + rem % window[a].1;
                rem /= window[a].1;
            }
            data[lin(global, &idx)]
        })
        .collect()
}

/// Forward + roundtrip check for a c2c plan against the serial reference.
fn check_c2c(global: &[usize], grid_ndims: usize, nprocs: usize, method: RedistMethod) {
    let global = global.to_vec();
    World::run(nprocs, move |comm| {
        let dims = a2wfft::simmpi::dims_create(comm.size(), grid_ndims);
        let mut plan = PfftPlan::<f64>::with_dims(&comm, &global, &dims, Kind::C2c, method);
        let mut eng = NativeFft::<f64>::new();
        let input = fill_local(&global, &plan.input_window());
        let mut output = vec![Complex64::ZERO; plan.output_len()];
        plan.forward(&mut eng, &input, &mut output);
        // Compare against this rank's window of the serial reference.
        let reference = serial_reference(&global, Direction::Forward);
        let want = window_of(&global, &reference, &plan.output_window());
        let scale: f64 = global.iter().product::<usize>() as f64;
        let err = max_abs_diff(&output, &want) / scale.max(1.0);
        assert!(err < 1e-12, "rank {}: forward err {err}", comm.rank());
        // Roundtrip.
        let mut back = vec![Complex64::ZERO; plan.input_len()];
        plan.backward(&mut eng, &output, &mut back);
        let err = max_abs_diff(&back, &input);
        assert!(err < 1e-10, "rank {}: roundtrip err {err}", comm.rank());
        // Timers recorded something.
        assert!(plan.timers.fft > 0.0);
        if comm.size() > 1 {
            assert!(plan.timers.redist > 0.0);
        }
    });
}

#[test]
fn slab_3d_c2c() {
    check_c2c(&[8, 12, 10], 1, 4, RedistMethod::Alltoallw);
}

#[test]
fn slab_3d_c2c_traditional() {
    check_c2c(&[8, 12, 10], 1, 4, RedistMethod::Traditional);
}

#[test]
fn slab_3d_uneven() {
    check_c2c(&[7, 9, 5], 1, 3, RedistMethod::Alltoallw);
}

#[test]
fn pencil_3d_c2c() {
    check_c2c(&[8, 12, 10], 2, 6, RedistMethod::Alltoallw);
}

#[test]
fn pencil_3d_c2c_traditional() {
    check_c2c(&[8, 12, 10], 2, 6, RedistMethod::Traditional);
}

#[test]
fn pencil_3d_uneven_grid() {
    // 7 x 9 x 5 over a 3 x 2 grid: nothing divides evenly.
    check_c2c(&[7, 9, 5], 2, 6, RedistMethod::Alltoallw);
}

#[test]
fn pencil_4d_c2c() {
    // 4-D array on a 2-D grid.
    check_c2c(&[6, 8, 4, 5], 2, 4, RedistMethod::Alltoallw);
}

#[test]
fn grid3d_4d_c2c_appendix_b() {
    // The paper's Appendix B shape class: 4-D array, 3-D process grid.
    check_c2c(&[6, 6, 6, 6], 3, 8, RedistMethod::Alltoallw);
}

#[test]
fn grid3d_4d_uneven() {
    check_c2c(&[5, 7, 6, 4], 3, 8, RedistMethod::Traditional);
}

#[test]
fn slab_2d_c2c() {
    check_c2c(&[16, 12], 1, 4, RedistMethod::Alltoallw);
}

#[test]
fn single_rank_matches_serial() {
    check_c2c(&[4, 6, 8], 1, 1, RedistMethod::Alltoallw);
}

#[test]
fn methods_agree_bitwise() {
    // The two redistribution methods must give *identical* spectra.
    let global = vec![8usize, 12, 10];
    let outs = World::run(6, |comm| {
        let mut eng = NativeFft::<f64>::new();
        let mut res = Vec::new();
        for method in [RedistMethod::Alltoallw, RedistMethod::Traditional] {
            let mut plan = PfftPlan::<f64>::with_dims(&comm, &global, &[3, 2], Kind::C2c, method);
            let input = fill_local(&global, &plan.input_window());
            let mut output = vec![Complex64::ZERO; plan.output_len()];
            plan.forward(&mut eng, &input, &mut output);
            res.push(output);
        }
        let eq = res[0]
            .iter()
            .zip(&res[1])
            .all(|(a, b)| a.re.to_bits() == b.re.to_bits() && a.im.to_bits() == b.im.to_bits());
        assert!(eq, "rank {}: methods differ bitwise", comm.rank());
        true
    });
    assert!(outs.into_iter().all(|x| x));
}

#[test]
fn r2c_pencil_matches_serial() {
    let global = vec![8usize, 6, 10];
    World::run(4, |comm| {
        let mut plan = PfftPlan::<f64>::with_dims(&comm, &global, &[2, 2], Kind::R2c, RedistMethod::Alltoallw);
        let mut eng = NativeFft::<f64>::new();
        // Real input: the real part of the test field.
        let win = plan.input_window();
        let input: Vec<f64> = fill_local(&global, &win).iter().map(|c| c.re).collect();
        let mut output = vec![Complex64::ZERO; plan.output_len()];
        plan.forward_r2c(&mut eng, &input, &mut output);
        // Serial reference: full c2c of the real field, truncated last axis.
        let total: usize = global.iter().product();
        let mut reference: Vec<Complex64> =
            (0..total).map(|g| Complex64::new(field(g).re, 0.0)).collect();
        let mut planner = Planner::new();
        for a in (0..3).rev() {
            fft_axis(&mut planner, &mut reference, &global, a, Direction::Forward);
        }
        let global_c = vec![global[0], global[1], global[2] / 2 + 1];
        // Build the truncated global reference.
        let mut ref_c = vec![Complex64::ZERO; global_c.iter().product()];
        for i0 in 0..global[0] {
            for i1 in 0..global[1] {
                for k in 0..global_c[2] {
                    ref_c[lin(&global_c, &[i0, i1, k])] = reference[lin(&global, &[i0, i1, k])];
                }
            }
        }
        let want = window_of(&global_c, &ref_c, &plan.output_window());
        let err = max_abs_diff(&output, &want) / total as f64;
        assert!(err < 1e-12, "rank {}: r2c err {err}", comm.rank());
        // Roundtrip c2r.
        let mut back = vec![0.0f64; plan.input_len()];
        plan.backward_c2r(&mut eng, &output, &mut back);
        let err =
            input.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-10, "rank {}: c2r roundtrip err {err}", comm.rank());
    });
}

#[test]
fn r2c_slab_odd_last_axis() {
    let global = vec![6usize, 4, 9];
    World::run(3, |comm| {
        let mut plan = PfftPlan::<f64>::with_dims(&comm, &global, &[3], Kind::R2c, RedistMethod::Alltoallw);
        let mut eng = NativeFft::<f64>::new();
        let win = plan.input_window();
        let input: Vec<f64> = fill_local(&global, &win).iter().map(|c| c.re).collect();
        let mut output = vec![Complex64::ZERO; plan.output_len()];
        plan.forward_r2c(&mut eng, &input, &mut output);
        let mut back = vec![0.0f64; plan.input_len()];
        plan.backward_c2r(&mut eng, &output, &mut back);
        let err = input.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-10, "rank {}: odd c2r roundtrip err {err}", comm.rank());
    });
}

#[test]
fn r2c_pencil_with_pooled_batched_engine() {
    // The ISSUE's distributed acceptance shape: 16x12x10 r2c/c2r over a
    // pencil grid with a lanes=8/threads=4 engine per rank — bitwise
    // equal spectra to the scalar engine, exact roundtrip tolerance.
    use a2wfft::fft::{EngineCfg, SerialFft};
    let global = vec![16usize, 12, 10];
    World::run(4, |comm| {
        let mut plan =
            PfftPlan::<f64>::with_dims(&comm, &global, &[2, 2], Kind::R2c, RedistMethod::Alltoallw);
        let input: Vec<f64> =
            fill_local(&global, &plan.input_window()).iter().map(|c| c.re).collect();
        let mut spectra: Vec<Vec<Complex64>> = Vec::new();
        let engines: Vec<Box<dyn SerialFft<f64>>> = vec![
            Box::new(NativeFft::<f64>::new()),
            Box::new(NativeFft::<f64>::with_cfg(EngineCfg::new(8, 4))),
        ];
        for (i, mut eng) in engines.into_iter().enumerate() {
            let mut output = vec![Complex64::ZERO; plan.output_len()];
            plan.forward_r2c(eng.as_mut(), &input, &mut output);
            let mut back = vec![0.0f64; plan.input_len()];
            plan.backward_c2r(eng.as_mut(), &output, &mut back);
            let err =
                input.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
            assert!(err < 1e-10, "rank {}: engine {i} roundtrip err {err}", comm.rank());
            spectra.push(output);
        }
        let eq = spectra[0]
            .iter()
            .zip(&spectra[1])
            .all(|(a, b)| a.re.to_bits() == b.re.to_bits() && a.im.to_bits() == b.im.to_bits());
        assert!(eq, "rank {}: pooled engine spectra differ bitwise from scalar", comm.rank());
    });
}

#[test]
fn linearity_of_distributed_transform() {
    let global = vec![8usize, 8, 6];
    World::run(4, |comm| {
        let mut plan = PfftPlan::<f64>::with_dims(&comm, &global, &[2, 2], Kind::C2c, RedistMethod::Alltoallw);
        let mut eng = NativeFft::<f64>::new();
        let x = fill_local(&global, &plan.input_window());
        let y: Vec<Complex64> = x.iter().map(|c| c.mul_i() + Complex64::new(0.5, 0.0)).collect();
        let mut fx = vec![Complex64::ZERO; plan.output_len()];
        let mut fy = vec![Complex64::ZERO; plan.output_len()];
        let mut fxy = vec![Complex64::ZERO; plan.output_len()];
        plan.forward(&mut eng, &x, &mut fx);
        plan.forward(&mut eng, &y, &mut fy);
        let xy: Vec<Complex64> = x.iter().zip(&y).map(|(&a, &b)| a + b.scale(2.0)).collect();
        plan.forward(&mut eng, &xy, &mut fxy);
        let want: Vec<Complex64> = fx.iter().zip(&fy).map(|(&a, &b)| a + b.scale(2.0)).collect();
        let scale: f64 = global.iter().product::<usize>() as f64;
        assert!(max_abs_diff(&fxy, &want) / scale < 1e-12);
    });
}
