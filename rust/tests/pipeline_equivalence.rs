//! Equivalence suite for the pipelined/persistent execution paths: every
//! overlapped or plan-reusing schedule must produce **bitwise-identical**
//! results to the blocking one-shot `alltoallw` exchange — chunking and
//! overlap only reorder the data movement, never the data.

use a2wfft::decomp::decompose;
use a2wfft::fft::{Complex64, NativeFft};
use a2wfft::pfft::{ExecMode, Kind, PfftPlan, RedistMethod};
use a2wfft::redistribute::{exchange, subarray_types, PipelinedRedistPlan, RedistPlan};
use a2wfft::simmpi::World;

/// Small deterministic PRNG (xorshift64*), as in `property_invariants`.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

#[test]
fn pipelined_redist_bitwise_matches_blocking_random_cases() {
    let mut rng = Rng::new(11);
    for case in 0..20 {
        let d = rng.range(3, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let nprocs = rng.range(2, 5);
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let chunks = rng.range(1, 6);
        let depth = rng.range(1, chunks);
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = decompose(global_c[axis_a], m, me).0;
            let mut lr = Rng::new(seed ^ (me as u64 + 1));
            let a: Vec<f64> =
                (0..sizes_a.iter().product::<usize>()).map(|_| lr.f64()).collect();
            let mut blocking = vec![0.0f64; sizes_b.iter().product()];
            exchange(&comm, &a, &sizes_a, axis_a, &mut blocking, &sizes_b, axis_b);
            let mut plan = PipelinedRedistPlan::new(
                &comm, 8, &sizes_a, axis_a, &sizes_b, axis_b, chunks, depth,
            );
            let mut piped = vec![0.0f64; sizes_b.iter().product()];
            plan.execute(&a, &mut piped);
            let bitwise = blocking
                .iter()
                .zip(&piped)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(
                bitwise,
                "case {case} rank {me}: pipelined (chunks={chunks}, depth={depth}) != blocking"
            );
            // And the reverse path restores A bitwise.
            let mut back = vec![0.0f64; a.len()];
            plan.execute_back(&piped, &mut back);
            assert!(
                a.iter().zip(&back).all(|(x, y)| x.to_bits() == y.to_bits()),
                "case {case} rank {me}: pipelined roundtrip not bitwise"
            );
        });
    }
}

#[test]
fn overlap_depth_sweep_is_invariant() {
    // Same exchange, every (chunks, depth) combination: all results equal.
    let global = [8usize, 10, 6];
    World::run(4, |comm| {
        let m = comm.size();
        let me = comm.rank();
        let sizes_a = [global[0], decompose(global[1], m, me).0, global[2]];
        let sizes_b = [decompose(global[0], m, me).0, global[1], global[2]];
        let a: Vec<f64> =
            (0..sizes_a.iter().product::<usize>()).map(|k| (me * 7919 + k) as f64).collect();
        let mut reference = vec![0.0f64; sizes_b.iter().product()];
        exchange(&comm, &a, &sizes_a, 0, &mut reference, &sizes_b, 1);
        for chunks in [1usize, 2, 3, 6] {
            for depth in [1usize, 2, chunks.max(1)] {
                let mut plan = PipelinedRedistPlan::new(
                    &comm, 8, &sizes_a, 0, &sizes_b, 1, chunks, depth,
                );
                let mut got = vec![0.0f64; reference.len()];
                plan.execute(&a, &mut got);
                assert_eq!(
                    reference, got,
                    "rank {me}: chunks={chunks} depth={depth} diverged"
                );
            }
        }
    });
}

#[test]
fn persistent_plan_three_executions_bitwise_stable() {
    // The satellite requirement: >= 3 executions of one persistent plan,
    // each bitwise identical to the blocking collective on the same data.
    World::run(4, |comm| {
        let me = comm.rank();
        let sizes = [4usize, 12, 5];
        // Partition axis 1 for sends, axis 0 of the transposed shape for
        // receives — the standard slab exchange datatypes.
        let sizes_b = [16usize, 3, 5];
        let send_t = subarray_types(&sizes, 1, 4, 8);
        let recv_t = subarray_types(&sizes_b, 0, 4, 8);
        let plan = comm.alltoallw_init(&send_t, &recv_t);
        for round in 0..3 {
            let a: Vec<f64> = (0..sizes.iter().product::<usize>())
                .map(|k| ((me + 1) * (round + 2) * 1000 + k) as f64 * 1.25)
                .collect();
            let mut blocking = vec![0.0f64; sizes_b.iter().product()];
            comm.alltoallw_typed(&a, &send_t, &mut blocking, &recv_t);
            let mut persistent = vec![0.0f64; sizes_b.iter().product()];
            plan.execute_typed(&a, &mut persistent);
            let bitwise = blocking
                .iter()
                .zip(&persistent)
                .all(|(x, y)| x.to_bits() == y.to_bits());
            assert!(bitwise, "rank {me} round {round}: persistent plan diverged");
        }
    });
}

#[test]
fn compiled_redist_plan_fused_path_bitwise_matches_oneshot() {
    // The compiled RedistPlan routes the intra-rank block through a fused
    // TransferPlan (no staging buffer) and the wire blocks through
    // arena-recycled persistent collectives; reused >= 3 times it must stay
    // bitwise identical to the raw blocking alltoallw on the same types.
    let mut rng = Rng::new(23);
    for case in 0..10 {
        let d = rng.range(3, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let nprocs = rng.range(1, 4); // nprocs == 1 exercises the pure fused path
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = decompose(global_c[axis_a], m, me).0;
            let send_t = subarray_types(&sizes_a, axis_a, m, 8);
            let recv_t = subarray_types(&sizes_b, axis_b, m, 8);
            let plan = RedistPlan::new(&comm, 8, &sizes_a, axis_a, &sizes_b, axis_b);
            for round in 0..3 {
                let mut lr = Rng::new(seed ^ ((me * 31 + round + 1) as u64));
                let a: Vec<f64> =
                    (0..sizes_a.iter().product::<usize>()).map(|_| lr.f64()).collect();
                let mut reference = vec![0.0f64; sizes_b.iter().product()];
                comm.alltoallw_typed(&a, &send_t, &mut reference, &recv_t);
                let mut compiled = vec![0.0f64; sizes_b.iter().product()];
                plan.execute(&a, &mut compiled);
                let bitwise = reference
                    .iter()
                    .zip(&compiled)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(bitwise, "case {case} rank {me} round {round}: fused path diverged");
                // Reverse direction through the compiled bwd plan.
                let mut back = vec![0.0f64; a.len()];
                plan.execute_back(&compiled, &mut back);
                assert!(
                    a.iter().zip(&back).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "case {case} rank {me} round {round}: fused roundtrip diverged"
                );
            }
        });
    }
}

/// Forward spectra of the same input under blocking and pipelined
/// execution must agree bitwise (the per-line serial transforms are
/// identical; only their interleaving with communication changes).
fn check_exec_modes_bitwise(global: &[usize], dims: &[usize], nprocs: usize, kind: Kind) {
    let global = global.to_vec();
    let dims = dims.to_vec();
    World::run(nprocs, move |comm| {
        let mut eng = NativeFft::<f64>::new();
        let mut spectra: Vec<Vec<Complex64>> = Vec::new();
        for exec in [
            ExecMode::Blocking,
            ExecMode::Pipelined { depth: 2 },
            ExecMode::Pipelined { depth: 4 },
        ] {
            let mut plan = PfftPlan::<f64>::with_exec(
                &comm,
                &global,
                &dims,
                kind,
                RedistMethod::Alltoallw,
                exec,
            );
            let mut output = vec![Complex64::ZERO; plan.output_len()];
            match kind {
                Kind::C2c => {
                    let input: Vec<Complex64> = (0..plan.input_len())
                        .map(|k| {
                            Complex64::new(
                                ((k * 31 + comm.rank() * 7) % 101) as f64 / 101.0,
                                ((k * 17) % 89) as f64 / 89.0,
                            )
                        })
                        .collect();
                    plan.forward(&mut eng, &input, &mut output);
                }
                Kind::R2c => {
                    let input: Vec<f64> = (0..plan.input_len())
                        .map(|k| ((k * 31 + comm.rank() * 7) % 101) as f64 / 101.0)
                        .collect();
                    plan.forward_r2c(&mut eng, &input, &mut output);
                }
            }
            spectra.push(output);
        }
        for (i, spec) in spectra.iter().enumerate().skip(1) {
            let bitwise = spectra[0].iter().zip(spec).all(|(x, y)| {
                x.re.to_bits() == y.re.to_bits() && x.im.to_bits() == y.im.to_bits()
            });
            assert!(bitwise, "rank {}: exec mode variant {i} diverged", comm.rank());
        }
    });
}

#[test]
fn pfft_slab_c2c_exec_modes_bitwise_equal() {
    check_exec_modes_bitwise(&[8, 12, 10], &[4], 4, Kind::C2c);
}

#[test]
fn pfft_pencil_c2c_exec_modes_bitwise_equal() {
    check_exec_modes_bitwise(&[8, 12, 10], &[3, 2], 6, Kind::C2c);
}

#[test]
fn pfft_pencil_r2c_exec_modes_bitwise_equal() {
    check_exec_modes_bitwise(&[8, 6, 10], &[2, 2], 4, Kind::R2c);
}

#[test]
fn pfft_pipelined_roundtrip_uneven() {
    // Uneven mesh over an uneven grid, full forward+backward in pipelined
    // mode: must reproduce the input to fp accuracy (same as blocking).
    let global = vec![7usize, 9, 5];
    World::run(3, |comm| {
        let mut plan = PfftPlan::<f64>::with_exec(
            &comm,
            &global,
            &[3],
            Kind::C2c,
            RedistMethod::Alltoallw,
            ExecMode::Pipelined { depth: 3 },
        );
        let mut eng = NativeFft::<f64>::new();
        let input: Vec<Complex64> = (0..plan.input_len())
            .map(|k| Complex64::new((k as f64 * 0.37).sin(), (k as f64 * 0.23).cos()))
            .collect();
        let mut spec = vec![Complex64::ZERO; plan.output_len()];
        plan.forward(&mut eng, &input, &mut spec);
        let mut back = vec![Complex64::ZERO; plan.input_len()];
        plan.backward(&mut eng, &spec, &mut back);
        let err = a2wfft::fft::max_abs_diff(&input, &back);
        assert!(err < 1e-10, "rank {}: pipelined roundtrip err {err}", comm.rank());
        // Overlap timers recorded the pipelined stages.
        assert!(plan.timers.overlap_fft + plan.timers.overlap_comm > 0.0);
        assert_eq!(plan.exec_mode(), ExecMode::Pipelined { depth: 3 });
    });
}
