//! Transport equivalence: the one-copy shared-window transport must be
//! **bitwise identical** to the mailbox transport at every layer —
//! redistribution plans, pipelined sub-exchanges, and full distributed
//! transforms — over random shapes, grids, methods, exec modes and dtypes
//! (deterministic xorshift sweeps; the offline crate set has no proptest).
//! Transport changes how bytes move, never what they are.

use a2wfft::fft::{Complex, NativeFft, Real};
use a2wfft::pfft::{ExecMode, Kind, PfftPlan, RedistMethod};
use a2wfft::redistribute::RedistPlan;
use a2wfft::simmpi::{as_bytes, dims_create, Transport, World};

/// Small deterministic PRNG (xorshift64*).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

#[test]
fn prop_redist_plan_window_bitwise_equals_mailbox() {
    let mut rng = Rng::new(41);
    for case in 0..15 {
        let d = rng.range(2, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let nprocs = rng.range(2, 5);
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = a2wfft::decomp::decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = a2wfft::decomp::decompose(global_c[axis_a], m, me).0;
            let mut lr = Rng::new(seed ^ (me as u64 + 1));
            let a: Vec<f64> =
                (0..sizes_a.iter().product::<usize>()).map(|_| lr.f64()).collect();
            let mailbox =
                RedistPlan::new(&comm, 8, &sizes_a, axis_a, &sizes_b, axis_b);
            let window = RedistPlan::with_transport(
                &comm, 8, &sizes_a, axis_a, &sizes_b, axis_b, Transport::Window,
            );
            let mut b_mail = vec![0.0f64; mailbox.elems_b()];
            mailbox.execute(&a, &mut b_mail);
            let mut b_win = vec![0.0f64; window.elems_b()];
            window.execute(&a, &mut b_win);
            assert_eq!(
                as_bytes(&b_mail),
                as_bytes(&b_win),
                "case {case} rank {me}: transports disagree"
            );
            let mut back = vec![0.0f64; window.elems_a()];
            window.execute_back(&b_win, &mut back);
            assert_eq!(as_bytes(&a), as_bytes(&back), "case {case} rank {me}: roundtrip");
        });
    }
}

/// One transform case at precision `T`: both transports (and, for blocking
/// alltoallw, the traditional mailbox baseline) must produce bitwise
/// identical spectra and roundtrip outputs.
fn transform_case<T: Real>(
    global: Vec<usize>,
    ranks: usize,
    grid_ndims: usize,
    kind: Kind,
    exec: ExecMode,
    seed: u64,
    case: usize,
) {
    World::run(ranks, move |comm| {
        let me = comm.rank();
        let dims = dims_create(comm.size(), grid_ndims);
        let mk = |transport: Transport| {
            PfftPlan::<T>::with_transport(
                &comm,
                &global,
                &dims,
                kind,
                RedistMethod::Alltoallw,
                exec,
                transport,
            )
        };
        let mut plan_mail = mk(Transport::Mailbox);
        let mut plan_win = mk(Transport::Window);
        assert_eq!(plan_win.transport(), Transport::Window, "case {case}");
        let mut engine = NativeFft::<T>::new();
        let ilen = plan_mail.input_len();
        let olen = plan_mail.output_len();
        let mut lr = Rng::new(seed ^ (me as u64).wrapping_mul(0x5851F42D4C957F2D));
        match kind {
            Kind::C2c => {
                let input: Vec<Complex<T>> = (0..ilen)
                    .map(|_| Complex::from_f64(lr.f64(), lr.f64()))
                    .collect();
                let mut spec_mail = vec![Complex::<T>::ZERO; olen];
                let mut spec_win = vec![Complex::<T>::ZERO; olen];
                plan_mail.forward(&mut engine, &input, &mut spec_mail);
                plan_win.forward(&mut engine, &input, &mut spec_win);
                assert_eq!(
                    as_bytes(&spec_mail),
                    as_bytes(&spec_win),
                    "case {case} rank {me} [{}]: spectra differ across transports",
                    T::NAME
                );
                let mut back_mail = vec![Complex::<T>::ZERO; ilen];
                let mut back_win = vec![Complex::<T>::ZERO; ilen];
                plan_mail.backward(&mut engine, &spec_mail, &mut back_mail);
                plan_win.backward(&mut engine, &spec_win, &mut back_win);
                assert_eq!(
                    as_bytes(&back_mail),
                    as_bytes(&back_win),
                    "case {case} rank {me}: roundtrips differ across transports"
                );
            }
            Kind::R2c => {
                let input: Vec<T> = (0..ilen).map(|_| T::from_f64(lr.f64())).collect();
                let mut spec_mail = vec![Complex::<T>::ZERO; olen];
                let mut spec_win = vec![Complex::<T>::ZERO; olen];
                plan_mail.forward_r2c(&mut engine, &input, &mut spec_mail);
                plan_win.forward_r2c(&mut engine, &input, &mut spec_win);
                assert_eq!(
                    as_bytes(&spec_mail),
                    as_bytes(&spec_win),
                    "case {case} rank {me} [{}]: r2c spectra differ across transports",
                    T::NAME
                );
                let mut back_mail = vec![T::ZERO; ilen];
                let mut back_win = vec![T::ZERO; ilen];
                plan_mail.backward_c2r(&mut engine, &spec_mail, &mut back_mail);
                plan_win.backward_c2r(&mut engine, &spec_win, &mut back_win);
                assert_eq!(
                    as_bytes(&back_mail),
                    as_bytes(&back_win),
                    "case {case} rank {me}: c2r roundtrips differ across transports"
                );
            }
        }
    });
}

#[test]
fn prop_transform_spectra_bitwise_equal_across_transports() {
    // Random shapes / ranks / grids / kinds / exec modes, both dtypes.
    let mut rng = Rng::new(42);
    for case in 0..10 {
        let d = rng.range(3, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(4, 11)).collect();
        let ranks = rng.range(2, 5);
        let grid_ndims = rng.range(1, (d - 1).min(2));
        let kind = if rng.below(2) == 0 { Kind::C2c } else { Kind::R2c };
        let exec = if rng.below(2) == 0 {
            ExecMode::Blocking
        } else {
            ExecMode::Pipelined { depth: rng.range(2, 4) }
        };
        let seed = rng.next_u64();
        if rng.below(2) == 0 {
            transform_case::<f64>(global, ranks, grid_ndims, kind, exec, seed, case);
        } else {
            transform_case::<f32>(global, ranks, grid_ndims, kind, exec, seed, case);
        }
    }
}

#[test]
fn window_alltoallw_matches_traditional_mailbox_baseline() {
    // Cross-method, cross-transport triangle at a fixed pencil case: the
    // paper's alltoallw on the window transport must agree bitwise with
    // the traditional remap+alltoallv baseline on the mailbox.
    World::run(4, |comm| {
        let me = comm.rank();
        let global = vec![8usize, 12, 6];
        let dims = dims_create(comm.size(), 2);
        let mut window = PfftPlan::<f64>::with_transport(
            &comm,
            &global,
            &dims,
            Kind::C2c,
            RedistMethod::Alltoallw,
            ExecMode::Blocking,
            Transport::Window,
        );
        let mut trad = PfftPlan::<f64>::with_dims(
            &comm,
            &global,
            &dims,
            Kind::C2c,
            RedistMethod::Traditional,
        );
        let mut engine = NativeFft::<f64>::new();
        let input: Vec<Complex<f64>> = (0..window.input_len())
            .map(|k| Complex::new((me * 1000 + k) as f64 * 0.25, (k as f64 * 0.5).sin()))
            .collect();
        let mut spec_win = vec![Complex::<f64>::ZERO; window.output_len()];
        let mut spec_trad = vec![Complex::<f64>::ZERO; trad.output_len()];
        window.forward(&mut engine, &input, &mut spec_win);
        trad.forward(&mut engine, &input, &mut spec_trad);
        assert_eq!(
            as_bytes(&spec_win),
            as_bytes(&spec_trad),
            "rank {me}: window alltoallw != traditional baseline"
        );
    });
}
