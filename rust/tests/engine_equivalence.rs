//! Serial-engine equivalence: every `EngineCfg` (lane-batched SoA
//! kernels, worker pool, and their combination) must produce **bitwise**
//! the same transforms as the scalar single-threaded reference engine.
//!
//! This is the acceptance gate of the vectorized+multithreaded engine:
//! the SoA kernels replay the scalar per-line operation order (identical
//! floating-point dataflow, only the schedule across independent lines
//! changes) and pool chunks partition disjoint lines, so there is no
//! tolerance here — `to_bits` equality, across:
//!
//! * plan kinds: pow2, mixed-radix smooth, direct prime, Bluestein prime;
//! * both precisions (`f32`/`f64`);
//! * thread counts {1, 2, 4} x lane widths {2, 4, 8, MAX_LANES};
//! * contiguous and strided axes, multi-axis sweeps, r2c/c2r.

use a2wfft::fft::{Complex, Direction, EngineCfg, NativeFft, Real, SerialFft, MAX_LANES};

/// Deterministic pseudo-random complex array (no external RNG crates).
fn test_data<T: Real>(len: usize, seed: u64) -> Vec<Complex<T>> {
    let mut state = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..len)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let re = ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0;
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let im = ((state >> 33) as f64 / (1u64 << 31) as f64) - 1.0;
            Complex::from_f64(re, im)
        })
        .collect()
}

fn bits<T: Real>(xs: &[Complex<T>]) -> Vec<(u64, u64)> {
    xs.iter().map(|c| (c.re.to_bits_u64(), c.im.to_bits_u64())).collect()
}

/// One pow2, one smooth (mixed-radix), one small direct prime, one
/// Bluestein prime — every serial plan kind.
const LENGTHS: &[usize] = &[16, 64, 360, 100, 13, 61, 67, 251];

const CFGS: &[(usize, usize)] = &[
    (2, 1),         // narrow SoA, no pool
    (4, 1),         // SoA only
    (MAX_LANES, 1), // widest SoA
    (1, 2),         // pool only
    (1, 4),         // wider pool
    (8, 2),         // combined
    (8, 4),         // combined, paper-like shape
];

fn check_c2c<T: Real>(n: usize, rows: usize) {
    // Contiguous (axis last) and strided (axis first) layouts.
    for (shape, axis) in [([rows, n], 1usize), ([n, rows], 0)] {
        let x: Vec<Complex<T>> = test_data(rows * n, (n * 31 + axis) as u64);
        for dir in [Direction::Forward, Direction::Backward] {
            let mut want = x.clone();
            NativeFft::<T>::new().c2c(&mut want, &shape, axis, dir);
            let want_bits = bits(&want);
            for &(lanes, threads) in CFGS {
                let cfg = EngineCfg::new(lanes, threads);
                let mut eng = NativeFft::<T>::with_cfg(cfg);
                let mut got = x.clone();
                eng.c2c(&mut got, &shape, axis, dir);
                assert_eq!(
                    bits(&got),
                    want_bits,
                    "{} n={n} rows={rows} axis={axis} {dir:?} diverges from scalar",
                    cfg.label()
                );
                // Re-running on the same (warm) engine is just as equal:
                // workspaces are reused, never re-derived.
                let mut again = x.clone();
                eng.c2c(&mut again, &shape, axis, dir);
                assert_eq!(bits(&again), want_bits, "{} warm rerun differs", cfg.label());
            }
        }
    }
}

#[test]
fn c2c_bitwise_equal_across_engine_cfgs_f64() {
    for &n in LENGTHS {
        check_c2c::<f64>(n, 9);
    }
}

#[test]
fn c2c_bitwise_equal_across_engine_cfgs_f32() {
    for &n in &[16usize, 360, 67, 251] {
        check_c2c::<f32>(n, 9);
    }
}

#[test]
fn c2c_bitwise_equal_when_rows_underfill_the_pool() {
    // Fewer rows than threads*lanes: chunk claiming must degrade cleanly.
    for rows in [1usize, 2, 3] {
        check_c2c::<f64>(64, rows);
        check_c2c::<f64>(67, rows);
    }
}

#[test]
fn multi_axis_sweep_bitwise_equal() {
    // A full 3-D forward sweep then backward sweep, every axis, comparing
    // the whole pipeline output — what pfft actually runs per rank.
    let shape = [12usize, 10, 8];
    let total: usize = shape.iter().product();
    let x: Vec<Complex<f64>> = test_data(total, 7);
    let sweep = |eng: &mut NativeFft<f64>| {
        let mut y = x.clone();
        for a in (0..3).rev() {
            eng.c2c(&mut y, &shape, a, Direction::Forward);
        }
        for a in 0..3 {
            eng.c2c(&mut y, &shape, a, Direction::Backward);
        }
        y
    };
    let want = bits(&sweep(&mut NativeFft::new()));
    for &(lanes, threads) in CFGS {
        let cfg = EngineCfg::new(lanes, threads);
        let got = bits(&sweep(&mut NativeFft::with_cfg(cfg)));
        assert_eq!(got, want, "{} multi-axis sweep diverges", cfg.label());
    }
}

fn check_r2c_c2r<T: Real>(n: usize, rows: usize) {
    let shape = [rows, n];
    let real: Vec<T> = test_data::<T>(rows * n, n as u64).iter().map(|c| c.re).collect();
    let nh = n / 2 + 1;
    let mut want_spec = vec![Complex::<T>::ZERO; rows * nh];
    let mut want_back = vec![T::ZERO; rows * n];
    let mut reference = NativeFft::<T>::new();
    reference.r2c(&real, &shape, &mut want_spec);
    reference.c2r(&want_spec, &shape, &mut want_back);
    let want_spec_bits = bits(&want_spec);
    let want_back_bits: Vec<u64> = want_back.iter().map(|v| v.to_bits_u64()).collect();
    for &(lanes, threads) in CFGS {
        let cfg = EngineCfg::new(lanes, threads);
        let mut eng = NativeFft::<T>::with_cfg(cfg);
        let mut spec = vec![Complex::<T>::ZERO; rows * nh];
        let mut back = vec![T::ZERO; rows * n];
        eng.r2c(&real, &shape, &mut spec);
        eng.c2r(&spec, &shape, &mut back);
        assert_eq!(bits(&spec), want_spec_bits, "{} r2c n={n} diverges", cfg.label());
        let back_bits: Vec<u64> = back.iter().map(|v| v.to_bits_u64()).collect();
        assert_eq!(back_bits, want_back_bits, "{} c2r n={n} diverges", cfg.label());
    }
}

#[test]
fn r2c_c2r_bitwise_equal_across_engine_cfgs() {
    for &n in &[16usize, 360, 100, 67] {
        check_r2c_c2r::<f64>(n, 11);
        check_r2c_c2r::<f32>(n, 11);
    }
}
