//! Dtype-correctness matrix: the precision-generic transform stack at
//! `f32` and `f64` across random shapes, grids and redistribution methods.
//!
//! * **roundtrip**: `bwd(fwd(x)) ≈ x` through the full distributed plan at
//!   both precisions, with tolerances scaled by the dtype's machine
//!   epsilon;
//! * **Parseval** per dtype: energy conservation of the serial 1-D plans;
//! * **bitwise fused-vs-staged**: the compiled `alltoallw` path and the
//!   traditional pack→`alltoallv`→unpack baseline are pure data movement,
//!   so their results must be *bit-identical* for `Complex32` payloads
//!   across random shapes/grids/methods — precision must not change what
//!   the datatype engine moves;
//! * **driver matrix**: `run_config` at `--dtype f32` over slab and pencil
//!   decompositions, both redistribution methods and both exec modes (the
//!   acceptance matrix of the precision-generic stack), wire bytes exactly
//!   half of the `f64` runs.

use a2wfft::coordinator::{run_config, Dtype, EngineKind, RunConfig};
use a2wfft::decomp::decompose;
use a2wfft::fft::{Complex, Complex32, Direction, FftPlan, NativeFft, Real};
use a2wfft::pfft::{ExecMode, Kind, PfftPlan, RedistMethod};
use a2wfft::redistribute::{exchange, traditional_exchange};
use a2wfft::simmpi::World;

/// Small deterministic PRNG (xorshift64*), as in `property_invariants`.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

/// Precision-scaled roundtrip tolerance: a generous multiple of epsilon
/// growing with sqrt(mesh size).
fn roundtrip_tol<T: Real>(total: usize) -> f64 {
    1e3 * T::EPSILON_F64 * (total as f64).sqrt().max(1.0)
}

/// Bitwise equality of two complex slices (no float comparison semantics).
fn bits_eq<T: Real>(a: &[Complex<T>], b: &[Complex<T>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.re.to_bits_u64() == y.re.to_bits_u64() && x.im.to_bits_u64() == y.im.to_bits_u64()
        })
}

/// Full distributed c2c forward+backward at precision `T` over a random
/// configuration; asserts the roundtrip error stays within the dtype's
/// scaled tolerance.
fn distributed_roundtrip<T: Real>(
    global: &[usize],
    dims: &[usize],
    nprocs: usize,
    method: RedistMethod,
    seed: u64,
) {
    let global = global.to_vec();
    let dims = dims.to_vec();
    let total: usize = global.iter().product();
    let tol = roundtrip_tol::<T>(total);
    World::run(nprocs, move |comm| {
        let mut plan = PfftPlan::<T>::with_dims(&comm, &global, &dims, Kind::C2c, method);
        assert_eq!(plan.dtype_name(), T::NAME, "plan must carry its precision");
        let mut eng = NativeFft::<T>::new();
        let mut lr = Rng::new(seed ^ (comm.rank() as u64 + 1));
        let input: Vec<Complex<T>> =
            (0..plan.input_len()).map(|_| Complex::from_f64(lr.f64(), lr.f64())).collect();
        let mut spec = vec![Complex::<T>::ZERO; plan.output_len()];
        plan.forward(&mut eng, &input, &mut spec);
        let mut back = vec![Complex::<T>::ZERO; plan.input_len()];
        plan.backward(&mut eng, &spec, &mut back);
        let err = a2wfft::fft::max_abs_diff(&input, &back);
        assert!(
            err < tol,
            "rank {}: {} roundtrip err {err} over tol {tol} (global {global:?}, dims {dims:?})",
            comm.rank(),
            plan.dtype_name(),
        );
    });
}

#[test]
fn prop_distributed_roundtrips_both_dtypes_random_cases() {
    let mut rng = Rng::new(41);
    for case in 0..6 {
        let d = rng.range(3, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(3, 9)).collect();
        let grid_ndims = rng.range(1, 2.min(d - 1));
        let nprocs = rng.range(2, 5);
        let dims = a2wfft::simmpi::dims_create(nprocs, grid_ndims);
        let method =
            if case % 2 == 0 { RedistMethod::Alltoallw } else { RedistMethod::Traditional };
        let seed = rng.next_u64();
        distributed_roundtrip::<f64>(&global, &dims, nprocs, method, seed);
        distributed_roundtrip::<f32>(&global, &dims, nprocs, method, seed);
    }
}

#[test]
fn parseval_per_dtype() {
    fn check<T: Real>(n: usize) {
        let mut rng = Rng::new(n as u64 + 9);
        let x: Vec<Complex<T>> = (0..n).map(|_| Complex::from_f64(rng.f64(), rng.f64())).collect();
        let plan = FftPlan::<T>::new(n);
        let mut y = x.clone();
        plan.process(&mut y, Direction::Forward);
        let ex: f64 = x.iter().map(|v| v.norm_sqr().to_f64()).sum();
        let ey: f64 = y.iter().map(|v| v.norm_sqr().to_f64()).sum::<f64>() / n as f64;
        let rel = (ex - ey).abs() / ex;
        let tol = 1e4 * T::EPSILON_F64;
        assert!(rel < tol, "{}: Parseval violated at n={n}: rel {rel} tol {tol}", T::NAME);
    }
    for n in [16usize, 60, 96, 127] {
        check::<f64>(n);
        check::<f32>(n);
    }
}

#[test]
fn prop_f32_fused_vs_staged_paths_bitwise_equal() {
    // The compiled alltoallw exchange (fused TransferPlan self-path, cached
    // flattenings) against the traditional staged baseline, on Complex32
    // payloads, over random shapes / axis pairs / group sizes: the results
    // must match bit for bit.
    let mut rng = Rng::new(77);
    for case in 0..12 {
        let d = rng.range(2, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 9)).collect();
        let nprocs = rng.range(1, 5);
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = decompose(global_c[axis_a], m, me).0;
            let mut lr = Rng::new(seed ^ (me as u64 + 1));
            let a: Vec<Complex32> = (0..sizes_a.iter().product::<usize>())
                .map(|_| Complex::from_f64(lr.f64(), lr.f64()))
                .collect();
            let mut fused = vec![Complex32::ZERO; sizes_b.iter().product()];
            exchange(&comm, &a, &sizes_a, axis_a, &mut fused, &sizes_b, axis_b);
            let mut staged = vec![Complex32::ZERO; sizes_b.iter().product()];
            traditional_exchange(&comm, &a, &sizes_a, axis_a, &mut staged, &sizes_b, axis_b);
            assert!(
                bits_eq(&fused, &staged),
                "case {case} rank {me}: f32 fused != staged bitwise"
            );
            // And the reverse fused path restores A bitwise.
            let mut back = vec![Complex32::ZERO; a.len()];
            exchange(&comm, &fused, &sizes_b, axis_b, &mut back, &sizes_a, axis_a);
            assert!(
                bits_eq(&a, &back),
                "case {case} rank {me}: f32 exchange roundtrip not bitwise"
            );
        });
    }
}

#[test]
fn f32_exec_modes_bitwise_equal_spectra() {
    // Pipelined vs blocking execution at single precision: chunking only
    // reorders data movement, so the f32 spectra must be bit-identical.
    let global = vec![8usize, 6, 10];
    World::run(4, |comm| {
        let mut eng = NativeFft::<f32>::new();
        let mut spectra: Vec<Vec<Complex32>> = Vec::new();
        for exec in [ExecMode::Blocking, ExecMode::Pipelined { depth: 3 }] {
            let mut plan = PfftPlan::<f32>::with_exec(
                &comm,
                &global,
                &[2, 2],
                Kind::R2c,
                RedistMethod::Alltoallw,
                exec,
            );
            let input: Vec<f32> = (0..plan.input_len())
                .map(|k| ((k * 31 + comm.rank() * 7) % 101) as f32 / 101.0)
                .collect();
            let mut output = vec![Complex32::ZERO; plan.output_len()];
            plan.forward_r2c(&mut eng, &input, &mut output);
            spectra.push(output);
        }
        assert!(
            bits_eq(&spectra[0], &spectra[1]),
            "rank {}: f32 exec modes diverged",
            comm.rank()
        );
    });
}

#[test]
fn driver_acceptance_matrix_f32() {
    // The acceptance matrix: --dtype f32 forward+backward over slab and
    // pencil decompositions, both redistribution methods, both exec modes
    // (pipelined requires alltoallw), within f32 tolerance — and wire
    // bytes exactly half of the same f64 configuration.
    let combos: &[(RedistMethod, ExecMode)] = &[
        (RedistMethod::Alltoallw, ExecMode::Blocking),
        (RedistMethod::Alltoallw, ExecMode::Pipelined { depth: 3 }),
        (RedistMethod::Traditional, ExecMode::Blocking),
    ];
    for grid_ndims in [1usize, 2] {
        for &(method, exec) in combos {
            let base = RunConfig {
                global: vec![16, 12, 10],
                ranks: 4,
                kind: Kind::R2c,
                method: method.into(),
                exec: exec.into(),
                engine: EngineKind::Native,
                inner: 1,
                outer: 1,
                ..Default::default()
            };
            let rep32 =
                run_config(&RunConfig { dtype: Dtype::F32, ..base.clone() }, grid_ndims);
            assert_eq!(rep32.dtype, "f32");
            assert!(
                rep32.max_err < Dtype::F32.roundtrip_tol(),
                "grid_ndims={grid_ndims} {method:?}/{exec:?}: f32 err {}",
                rep32.max_err
            );
            let rep64 = run_config(&base, grid_ndims);
            assert_eq!(
                rep32.bytes * 2,
                rep64.bytes,
                "grid_ndims={grid_ndims} {method:?}/{exec:?}: f32 bytes not half of f64"
            );
        }
    }
}
