//! Observability acceptance: the per-rank event tracer end to end.
//!
//! Covers the tentpole invariants: span nesting/ordering, counter-delta
//! byte attribution, deterministic gather across world sizes, zero
//! steady-state allocations with tracing **on**, reconciliation of span
//! sums against [`StageTimers`], and the Chrome-trace JSON the driver
//! writes for `--trace`.
//!
//! Tracing is a process-global switch and the gather sink is shared, so
//! every test here serializes on one mutex (the cargo harness runs tests
//! concurrently; an unguarded world would leak its bundle into another
//! test's drain). Uses the same thread-local counting allocator as
//! `alloc_steady_state.rs` for the allocation guarantee.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Mutex;

use a2wfft::coordinator::benchkit::report_json;
use a2wfft::coordinator::trend::JsonValue;
use a2wfft::coordinator::{run_config, RunConfig};
use a2wfft::fft::{Complex, NativeFft};
use a2wfft::pfft::{ExecMode, Kind, PfftPlan, RedistMethod};
use a2wfft::redistribute::PipelinedRedistPlan;
use a2wfft::simmpi::datatype::{stats, Datatype, TransferPlan};
use a2wfft::simmpi::{Transport, World};
use a2wfft::trace::{self, Category};

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: defers to the system allocator; the counter is a plain Cell of a
// primitive with no destructor, safe to touch from the allocator hook.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// Serializes every test that flips the process-global tracing switch.
static GUARD: Mutex<()> = Mutex::new(());

/// Enter the guarded tracing region with clean global state.
fn guarded() -> std::sync::MutexGuard<'static, ()> {
    let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    trace::set_enabled(false);
    let _ = trace::take_bundles();
    trace::clear_local();
    g
}

#[test]
fn span_nesting_and_ordering_invariants() {
    let _g = guarded();
    trace::set_enabled(true);
    {
        let _a = trace::span(Category::Fft, "outer");
        {
            let _b = trace::span(Category::Fft, "inner");
            let _c = trace::span(Category::Pack, "other");
        }
    }
    trace::set_enabled(false);
    let (spans, dropped) = trace::take_local();
    assert_eq!(dropped, 0);
    // Spans record in close order: innermost guards drop first.
    let labels: Vec<&str> = spans.iter().map(|s| s.label).collect();
    assert_eq!(labels, vec!["other", "inner", "outer"]);
    let by_label = |l: &str| spans.iter().find(|s| s.label == l).unwrap();
    let (outer, inner, other) = (by_label("outer"), by_label("inner"), by_label("other"));
    // Global depth counts every open span; category depth only same-cat.
    assert_eq!((outer.depth, outer.cat_depth), (0, 0));
    assert_eq!((inner.depth, inner.cat_depth), (1, 1));
    assert_eq!((other.depth, other.cat_depth), (2, 0));
    assert_eq!(other.cat, Category::Pack);
    // Timestamps nest: children open after and close before the parent.
    assert!(inner.begin_ns >= outer.begin_ns);
    assert!(inner.end_ns <= outer.end_ns);
    assert!(other.begin_ns >= inner.begin_ns);
    assert!(other.end_ns <= inner.end_ns);
    assert!(outer.end_ns >= outer.begin_ns);
}

#[test]
fn span_bytes_match_the_scoped_counter_delta() {
    let _g = guarded();
    let send = Datatype::subarray(&[8, 10, 6], &[4, 5, 6], &[2, 3, 0], 8).unwrap();
    let recv = Datatype::subarray(&[5, 9, 8], &[4, 5, 6], &[1, 2, 1], 8).unwrap();
    let plan = TransferPlan::compile(&send, &recv).unwrap();
    let src = vec![0xABu8; send.extent()];
    let mut dst = vec![0u8; recv.extent()];
    trace::set_enabled(true);
    let ((), d) = stats::scoped(|| {
        let _s = trace::span(Category::Exchange, "scripted");
        plan.execute(&src, &mut dst);
    });
    trace::set_enabled(false);
    let moved = d.fused_bytes + d.one_copy_bytes + d.packed_bytes + d.unpacked_bytes;
    assert!(moved > 0, "scripted workload moved no engine bytes");
    let (spans, dropped) = trace::take_local();
    assert_eq!(dropped, 0);
    // The outer span's byte delta is exactly what the scoped counter saw,
    // and the engine's own nested "fused" span attributes the same bytes.
    let outer = spans.iter().find(|s| s.label == "scripted").unwrap();
    assert_eq!(outer.bytes, moved);
    let fused = spans.iter().find(|s| s.label == "fused").unwrap();
    assert_eq!(fused.cat, Category::Pack);
    assert_eq!(fused.bytes, moved);
    assert!(fused.depth > outer.depth);
}

#[test]
fn gather_is_deterministic_across_world_sizes() {
    let _g = guarded();
    for n in [1usize, 2, 4] {
        trace::set_enabled(true);
        World::run(n, |comm| {
            // Rank r records r+1 spans: the gathered bundle must keep them
            // in rank order with exact counts, every size, every repeat.
            for _ in 0..=comm.rank() {
                let _s = trace::span(Category::Fft, "probe");
            }
        });
        trace::set_enabled(false);
        let bundles = trace::take_bundles();
        assert_eq!(bundles.len(), 1, "world of {n} must gather exactly one bundle");
        assert_eq!(bundles[0].ranks.len(), n);
        for (r, rank) in bundles[0].ranks.iter().enumerate() {
            assert_eq!(rank.dropped, 0);
            assert_eq!(rank.spans.len(), r + 1, "rank {r} of {n} span count");
            for s in &rank.spans {
                assert_eq!(s.cat, Category::Fft);
                assert_eq!(s.label, "probe");
                assert!(s.end_ns >= s.begin_ns);
            }
        }
    }
}

#[test]
fn tracing_on_steady_state_is_allocation_free() {
    let _g = guarded();
    trace::set_enabled(true);
    // Same workload as the alloc_steady_state pipelined test, but with the
    // tracer recording every pack/chunk span: after warmup primes the
    // arenas *and* the preallocated span ring, executions must still never
    // touch the heap.
    World::run(1, |comm| {
        let sizes = [4usize, 6, 8];
        let mut plan = PipelinedRedistPlan::new(&comm, 8, &sizes, 0, &sizes, 1, 4, 2);
        assert!(plan.is_pipelined());
        let a: Vec<f64> = (0..plan.elems_a()).map(|x| x as f64 * 1.5).collect();
        let mut b = vec![0.0f64; plan.elems_b()];
        let mut back = vec![0.0f64; plan.elems_a()];
        for _ in 0..2 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        assert_eq!(a, back, "roundtrip broken");
        let n0 = allocs_on_this_thread();
        for _ in 0..5 {
            plan.execute(&a, &mut b);
            plan.execute_back(&b, &mut back);
        }
        let delta = allocs_on_this_thread() - n0;
        assert_eq!(delta, 0, "tracing-on executions allocated {delta} times in 5 trips");
    });
    trace::set_enabled(false);
    let bundles = trace::take_bundles();
    assert_eq!(bundles.len(), 1);
    assert!(!bundles[0].ranks[0].spans.is_empty(), "no spans recorded while tracing");
}

#[test]
fn span_sums_reconcile_with_stage_timers() {
    let _g = guarded();
    let global = [16usize, 12, 10];
    let deltas = World::run(4, |comm| {
        let mut plan = PfftPlan::<f64>::with_transport(
            &comm,
            &global,
            &[2, 2],
            Kind::C2c,
            RedistMethod::Alltoallw,
            ExecMode::Blocking,
            Transport::Mailbox,
        );
        let mut engine = NativeFft::<f64>::new();
        let input: Vec<Complex<f64>> = (0..plan.input_len())
            .map(|k| Complex::from_f64((k as f64 * 0.61).sin(), (k as f64 * 0.23).cos()))
            .collect();
        let mut spec = vec![Complex::<f64>::ZERO; plan.output_len()];
        let mut back = vec![Complex::<f64>::ZERO; plan.input_len()];
        // Warm up untraced, then measure with a clean ring and timers so
        // the two clocks cover exactly the same pairs.
        plan.forward(&mut engine, &input, &mut spec);
        plan.backward(&mut engine, &spec, &mut back);
        trace::set_enabled(true);
        trace::clear_local();
        plan.timers.reset();
        comm.barrier();
        for _ in 0..2 {
            plan.forward(&mut engine, &input, &mut spec);
            plan.backward(&mut engine, &spec, &mut back);
        }
        let timers = plan.timers;
        let (spans, dropped) = trace::take_local();
        assert_eq!(dropped, 0);
        let sum = |cat: Category| -> f64 {
            spans
                .iter()
                .filter(|s| s.cat == cat && s.cat_depth == 0)
                .map(|s| s.end_ns.saturating_sub(s.begin_ns) as f64 * 1e-9)
                .sum()
        };
        (timers, sum(Category::Fft), sum(Category::Exchange))
    });
    trace::set_enabled(false);
    let _ = trace::take_bundles();
    // Blocking mode: summed outermost Fft spans cover the fft timer and
    // summed Exchange spans cover the redist timer, within 5% plus a small
    // absolute slop for clock-read placement at this tiny shape.
    for (rank, (timers, fft_s, exch_s)) in deltas.into_iter().enumerate() {
        assert!(timers.fft > 0.0 && timers.redist > 0.0, "rank {rank}: timers empty");
        assert_eq!(timers.overlap_fft, 0.0);
        assert_eq!(timers.overlap_comm, 0.0);
        let close = |spans: f64, timer: f64| (spans - timer).abs() <= 0.05 * timer + 2e-3;
        assert!(
            close(fft_s, timers.fft),
            "rank {rank}: fft spans {fft_s:.6}s vs timer {:.6}s",
            timers.fft
        );
        assert!(
            close(exch_s, timers.redist),
            "rank {rank}: exchange spans {exch_s:.6}s vs timer {:.6}s",
            timers.redist
        );
    }
}

#[test]
fn pool_worker_spans_rebase_under_rank_nesting() {
    // The serial engine's worker pool records spans on worker threads
    // (their own thread-local rings and depth counters), drains them into
    // preallocated sinks at job end, and the rank thread absorbs them at
    // join — re-based under whatever span the rank thread has open. The
    // rank thread's own nesting bookkeeping must come through untouched.
    use a2wfft::fft::WorkerPool;

    let _g = guarded();
    let pool = WorkerPool::new(4);
    let nworkers = pool.threads() - 1;
    trace::set_enabled(true);
    {
        let _outer = trace::span(Category::Fft, "rank_outer");
        pool.run(16, &|_wid, _chunk| {
            let _c = trace::span(Category::Pack, "pool_chunk");
        });
    }
    {
        // A fresh rank-side span after the join: if worker absorption had
        // corrupted the rank thread's depth counters, this would nest.
        let _post = trace::span(Category::Fft, "post_join");
    }
    trace::set_enabled(false);
    let (spans, dropped) = trace::take_local();
    assert_eq!(dropped, 0);
    // Every worker woke for the job and recorded exactly one job span.
    let workers: Vec<_> = spans.iter().filter(|s| s.label == "fft_pool_worker").collect();
    assert_eq!(workers.len(), nworkers, "one span per pool worker per job");
    for w in &workers {
        assert_eq!(w.cat, Category::Fft);
        // Outermost on the worker, re-based under the open "rank_outer"
        // span (global depth 1, same-category depth 1).
        assert_eq!((w.depth, w.cat_depth), (1, 1), "worker span not re-based");
    }
    // All 16 chunks recorded a span, whichever thread claimed them: inline
    // on the rank thread they sit directly under "rank_outer" (depth 1);
    // on a worker they sit under "fft_pool_worker" too (depth 2 after
    // re-basing). Pack nests under Fft only, so cat_depth stays 0.
    let chunks: Vec<_> = spans.iter().filter(|s| s.label == "pool_chunk").collect();
    assert_eq!(chunks.len(), 16, "every chunk records exactly one span");
    for c in &chunks {
        assert_eq!(c.cat, Category::Pack);
        assert!(c.depth == 1 || c.depth == 2, "chunk span depth {} out of range", c.depth);
        assert_eq!(c.cat_depth, 0);
    }
    // The rank thread's own spans kept clean depth accounting throughout.
    let outer = spans.iter().find(|s| s.label == "rank_outer").unwrap();
    assert_eq!((outer.depth, outer.cat_depth), (0, 0));
    let post = spans.iter().find(|s| s.label == "post_join").unwrap();
    assert_eq!((post.depth, post.cat_depth), (0, 0), "rank depth corrupted by absorption");
}

#[test]
fn pooled_engine_worker_spans_reach_the_world_gather() {
    // End to end: a lane-batched + pooled engine running inside a
    // simulated world must surface its workers' spans in the gathered
    // bundle of *its own rank*, with nothing dropped.
    use a2wfft::fft::{Direction, EngineCfg, SerialFft};

    let _g = guarded();
    trace::set_enabled(true);
    let n = 2;
    World::run(n, |comm| {
        let mut eng = NativeFft::<f64>::with_cfg(EngineCfg::new(8, 4));
        let shape = [48usize, 64];
        let mut data: Vec<Complex<f64>> = (0..shape[0] * shape[1])
            .map(|k| Complex::from_f64((k as f64 * 0.3).sin(), (k as f64 * 0.7).cos()))
            .collect();
        let _s = trace::span(Category::Fft, "rank_fft");
        eng.c2c(&mut data, &shape, 1, Direction::Forward);
        eng.c2c(&mut data, &shape, 1, Direction::Backward);
    });
    trace::set_enabled(false);
    let bundles = trace::take_bundles();
    assert_eq!(bundles.len(), 1);
    assert_eq!(bundles[0].ranks.len(), n);
    for (r, rank) in bundles[0].ranks.iter().enumerate() {
        assert_eq!(rank.dropped, 0, "rank {r} dropped worker spans");
        let workers = rank.spans.iter().filter(|s| s.label == "fft_pool_worker").count();
        assert!(workers >= 3, "rank {r}: only {workers} pool-worker spans gathered");
        for s in rank.spans.iter().filter(|s| s.label == "fft_pool_worker") {
            assert_eq!(s.cat, Category::Fft);
            assert!(s.end_ns >= s.begin_ns);
            assert!(s.depth >= 1, "worker span not nested under the rank span");
        }
    }
}

/// All `"X"` events of a parsed Chrome trace as (pid, cat, dur_us) rows.
fn x_events(doc: &JsonValue) -> Vec<(u64, String, f64)> {
    doc.get("traceEvents")
        .and_then(|v| v.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
        .map(|e| {
            (
                e.get("pid").and_then(|v| v.as_num()).unwrap() as u64,
                e.get("cat").and_then(|v| v.as_str()).unwrap().to_string(),
                e.get("dur").and_then(|v| v.as_num()).unwrap(),
            )
        })
        .collect()
}

#[test]
fn driver_trace_writes_valid_chrome_json_with_all_core_categories() {
    let _g = guarded();
    let path = std::env::temp_dir().join(format!("a2wfft_trace_run_{}.json", std::process::id()));
    let cfg = RunConfig {
        global: vec![16, 12, 10],
        ranks: 4,
        inner: 1,
        outer: 1,
        trace: Some(path.clone()),
        ..Default::default()
    };
    let rep = run_config(&cfg, 2);
    assert!(rep.max_err < 1e-9);
    // The driver disabled tracing and drained the sink itself.
    assert!(!trace::enabled());
    assert!(trace::take_bundles().is_empty());
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let doc = JsonValue::parse(&text).expect("trace file is not valid JSON");
    let events = x_events(&doc);
    // Every rank shows every core category of the blocking mailbox run.
    for pid in 0..4u64 {
        for cat in ["fft", "pack", "exchange", "wait"] {
            assert!(
                events.iter().any(|(p, c, _)| *p == pid && c == cat),
                "rank {pid} has no {cat} span among {} events",
                events.len()
            );
        }
    }
    // The embedded imbalance report covers the same stages, per rank.
    let imb = doc.get("imbalance").expect("imbalance object missing");
    let stages = imb.get("stages").and_then(|v| v.as_arr()).unwrap();
    assert!(stages.len() >= 4, "only {} imbalance stages", stages.len());
    for s in stages {
        assert_eq!(s.get("per_rank_s").and_then(|v| v.as_arr()).unwrap().len(), 4);
        assert!(s.get("skew").and_then(|v| v.as_num()).unwrap() >= 1.0 - 1e-9);
    }
    imb.get("critical").expect("critical path missing");
    // The run report surfaces the metric-level skew in JSON rows too.
    let row = JsonValue::parse(&report_json("t", &cfg.global, &[2, 2], 4, &rep)).unwrap();
    assert!(row.get("imb_total").and_then(|v| v.as_num()).unwrap() >= 1.0);
    assert!(row.get("imb_fft").and_then(|v| v.as_num()).is_some());
}

#[test]
fn pipelined_window_trace_records_window_and_chunk_spans() {
    let _g = guarded();
    let path = std::env::temp_dir().join(format!("a2wfft_trace_pipe_{}.json", std::process::id()));
    let cfg = RunConfig {
        global: vec![16, 12, 10],
        ranks: 4,
        exec: ExecMode::Pipelined { depth: 3 }.into(),
        transport: Transport::Window.into(),
        inner: 1,
        outer: 1,
        trace: Some(path.clone()),
        ..Default::default()
    };
    let rep = run_config(&cfg, 1);
    assert!(rep.max_err < 1e-9);
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let doc = JsonValue::parse(&text).expect("trace file is not valid JSON");
    let events = x_events(&doc);
    for cat in ["window", "chunk", "fft"] {
        assert!(
            events.iter().any(|(_, c, _)| c == cat),
            "pipelined window run recorded no {cat} spans"
        );
    }
}
