//! Property-based tests (deterministic xorshift sweeps — the offline crate
//! set has no proptest) over the system's core invariants:
//!
//! * decomposition: coverage, balance, monotone starts — for arbitrary N, M;
//! * datatype engine: pack/unpack roundtrip for random subarrays; packed
//!   size consistency; run-merging equivalence with the naive odometer;
//! * redistribution: exchange followed by its reverse is the identity, and
//!   the new method agrees element-wise with the traditional baseline, for
//!   random shapes / axis pairs / group sizes;
//! * nonblocking collectives: a batch of outstanding requests waited in an
//!   arbitrary per-rank permutation yields the same buffers as the
//!   blocking collectives (completion-order independence);
//! * serial FFT: random lengths vs the O(N^2) DFT.

use a2wfft::decomp::{decompose, decompose_all};
use a2wfft::fft::{max_abs_diff, naive_dft, Complex64, Direction, FftPlan};
use a2wfft::redistribute::{exchange, traditional_exchange};
use a2wfft::simmpi::datatype::{Datatype, TransferPlan};
use a2wfft::simmpi::World;

/// Small deterministic PRNG (xorshift64*).
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 - 0.5
    }
}

#[test]
fn prop_decompose_invariants() {
    let mut rng = Rng::new(1);
    for _ in 0..500 {
        let n = rng.below(2000);
        let m = rng.range(1, 64);
        let parts = decompose_all(n, m);
        let mut covered = 0usize;
        let mut prev_len = usize::MAX;
        for (p, &(len, start)) in parts.iter().enumerate() {
            assert_eq!(start, covered, "n={n} m={m} p={p}");
            covered += len;
            assert!(len <= prev_len, "lengths must be non-increasing");
            prev_len = len;
            assert_eq!((len, start), decompose(n, m, p));
        }
        assert_eq!(covered, n);
        // Balance: max - min <= 1.
        let lens: Vec<usize> = parts.iter().map(|&(l, _)| l).collect();
        assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
    }
}

#[test]
fn prop_subarray_pack_unpack_roundtrip() {
    let mut rng = Rng::new(2);
    for case in 0..200 {
        let d = rng.range(1, 4);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(1, 9)).collect();
        let subsizes: Vec<usize> = sizes.iter().map(|&s| rng.range(0, s)).collect();
        let starts: Vec<usize> =
            sizes.iter().zip(&subsizes).map(|(&s, &ss)| rng.below(s - ss + 1)).collect();
        let elem = [1usize, 2, 4, 8][rng.below(4)];
        let dt = Datatype::subarray(&sizes, &subsizes, &starts, elem)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let total = sizes.iter().product::<usize>() * elem;
        let src: Vec<u8> = (0..total).map(|_| rng.next_u64() as u8).collect();
        let packed = dt.pack_to_vec(&src);
        assert_eq!(packed.len(), dt.packed_size());
        // Unpack into a clean buffer, re-pack: must match.
        let mut dst = vec![0u8; total];
        dt.unpack(&packed, &mut dst);
        let repacked = dt.pack_to_vec(&dst);
        assert_eq!(packed, repacked, "case {case}: pack(unpack(x)) != x");
        // Run decomposition bookkeeping.
        let runs = dt.runs();
        assert_eq!(runs.count() * runs.run_len, dt.packed_size(), "case {case}");
    }
}

/// Draw a random subarray datatype that selects exactly `subsizes` (in
/// some random enclosing array), for the transfer-plan properties below.
fn random_enclosing(rng: &mut Rng, subsizes: &[usize], elem: usize) -> Datatype {
    let sizes: Vec<usize> = subsizes.iter().map(|&ss| ss + rng.below(5)).collect();
    let starts: Vec<usize> =
        sizes.iter().zip(subsizes).map(|(&s, &ss)| rng.below(s - ss + 1)).collect();
    Datatype::subarray(&sizes, subsizes, &starts, elem).unwrap()
}

#[test]
fn prop_transfer_plan_fused_bitwise_equals_staged_pack_unpack() {
    // For random (send, recv) datatype pairs selecting the same number of
    // bytes, the fused TransferPlan copy must be bitwise identical to the
    // reference semantics: pack through a contiguous staging buffer, then
    // unpack — including every byte *outside* the selection (untouched).
    let mut rng = Rng::new(21);
    for case in 0..200 {
        let d = rng.range(1, 4);
        let subsizes: Vec<usize> = (0..d).map(|_| rng.range(0, 6)).collect();
        let elem = [1usize, 2, 4, 8][rng.below(4)];
        let send = random_enclosing(&mut rng, &subsizes, elem);
        // The receive side selects the same block, possibly through a
        // permuted-axes enclosing shape (same products, different run
        // structure).
        let mut recv_sub = subsizes.clone();
        if d > 1 && rng.below(2) == 0 {
            let i = rng.below(d);
            let j = rng.below(d);
            recv_sub.swap(i, j);
        }
        let recv = random_enclosing(&mut rng, &recv_sub, elem);
        let plan = TransferPlan::compile(&send, &recv)
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        let src: Vec<u8> = (0..send.extent()).map(|_| rng.next_u64() as u8).collect();
        // Reference: staged pack -> unpack.
        let staging = send.pack_to_vec(&src);
        let mut want = vec![0x5Au8; recv.extent()];
        recv.unpack(&staging, &mut want);
        // Fused, over the same initial destination contents.
        let mut got = vec![0x5Au8; recv.extent()];
        plan.execute(&src, &mut got);
        assert_eq!(got, want, "case {case}: fused != staged");
        assert_eq!(plan.bytes(), send.packed_size(), "case {case}: byte accounting");
    }
}

#[test]
fn prop_transfer_plan_reuse_never_diverges_from_one_shot() {
    // A plan compiled once and executed >= 3 times over changing data must
    // match a freshly compiled plan (and the staged reference) every time.
    let mut rng = Rng::new(22);
    for case in 0..50 {
        let d = rng.range(2, 4);
        let subsizes: Vec<usize> = (0..d).map(|_| rng.range(1, 5)).collect();
        let elem = [1usize, 4, 8][rng.below(3)];
        let send = random_enclosing(&mut rng, &subsizes, elem);
        let recv = random_enclosing(&mut rng, &subsizes, elem);
        let reused = TransferPlan::compile(&send, &recv).unwrap();
        for round in 0..3 {
            let src: Vec<u8> = (0..send.extent()).map(|_| rng.next_u64() as u8).collect();
            let one_shot = TransferPlan::compile(&send, &recv).unwrap();
            let mut via_reused = vec![0u8; recv.extent()];
            reused.execute(&src, &mut via_reused);
            let mut via_fresh = vec![0u8; recv.extent()];
            one_shot.execute(&src, &mut via_fresh);
            assert_eq!(via_reused, via_fresh, "case {case} round {round}: reuse diverged");
            let staging = send.pack_to_vec(&src);
            let mut staged = vec![0u8; recv.extent()];
            recv.unpack(&staging, &mut staged);
            assert_eq!(via_reused, staged, "case {case} round {round}: plan != staged");
        }
    }
}

#[test]
fn prop_runs_match_naive_odometer() {
    let mut rng = Rng::new(3);
    for _ in 0..100 {
        let d = rng.range(2, 5);
        let sizes: Vec<usize> = (0..d).map(|_| rng.range(1, 6)).collect();
        let subsizes: Vec<usize> = sizes.iter().map(|&s| rng.range(1, s)).collect();
        let starts: Vec<usize> =
            sizes.iter().zip(&subsizes).map(|(&s, &ss)| rng.below(s - ss + 1)).collect();
        let dt = Datatype::subarray(&sizes, &subsizes, &starts, 1).unwrap();
        // Collect all selected offsets via the run decomposition.
        let runs = dt.runs();
        let mut via_runs = Vec::new();
        runs.for_each_offset(|o| via_runs.extend(o..o + runs.run_len));
        // Naive enumeration in row-major order.
        let mut naive = Vec::new();
        let mut idx = vec![0usize; d];
        loop {
            let mut off = 0;
            for a in 0..d {
                off = off * sizes[a] + starts[a] + idx[a];
            }
            naive.push(off);
            let mut a = d;
            loop {
                if a == 0 {
                    break;
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < subsizes[a] {
                    break;
                }
                idx[a] = 0;
            }
            if idx.iter().all(|&i| i == 0) {
                break;
            }
        }
        assert_eq!(via_runs, naive, "sizes={sizes:?} sub={subsizes:?} starts={starts:?}");
    }
}

#[test]
fn prop_exchange_roundtrip_and_method_agreement() {
    let mut rng = Rng::new(4);
    for case in 0..25 {
        let d = rng.range(2, 4);
        let global: Vec<usize> = (0..d).map(|_| rng.range(2, 10)).collect();
        let nprocs = rng.range(2, 5);
        let axis_a = rng.below(d);
        let mut axis_b = rng.below(d);
        while axis_b == axis_a {
            axis_b = rng.below(d);
        }
        let seed = rng.next_u64();
        let global_c = global.clone();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let mut sizes_a = global_c.clone();
            let mut sizes_b = global_c.clone();
            sizes_a[axis_b] = decompose(global_c[axis_b], m, me).0;
            sizes_b[axis_a] = decompose(global_c[axis_a], m, me).0;
            let elems_a: usize = sizes_a.iter().product();
            let mut lr = Rng::new(seed ^ (me as u64 + 1));
            let a: Vec<f64> = (0..elems_a).map(|_| lr.f64()).collect();
            let mut b1 = vec![0.0f64; sizes_b.iter().product()];
            let mut b2 = vec![0.0f64; sizes_b.iter().product()];
            exchange(&comm, &a, &sizes_a, axis_a, &mut b1, &sizes_b, axis_b);
            traditional_exchange(&comm, &a, &sizes_a, axis_a, &mut b2, &sizes_b, axis_b);
            assert_eq!(b1, b2, "case {case}: methods disagree");
            // Reverse exchange restores A.
            let mut back = vec![0.0f64; elems_a];
            exchange(&comm, &b1, &sizes_b, axis_b, &mut back, &sizes_a, axis_a);
            assert_eq!(a, back, "case {case}: roundtrip failed");
        });
    }
}

#[test]
fn prop_waitall_completion_order_independence() {
    // N outstanding nonblocking collectives, waited in a random (per-rank,
    // per-case) permutation: every buffer must match the corresponding
    // blocking collective. Initiation order is identical on all ranks (the
    // MPI ordering rule); completion order is deliberately scrambled and
    // may differ across ranks.
    let mut rng = Rng::new(7);
    for case in 0..12 {
        let nprocs = rng.range(2, 5);
        let nops = rng.range(2, 6);
        let seed = rng.next_u64();
        World::run(nprocs, move |comm| {
            let m = comm.size();
            let me = comm.rank();
            let counts = vec![3usize; m];
            let displs: Vec<usize> = (0..m).map(|p| 3 * p).collect();
            let mut lr = Rng::new(seed ^ (me as u64).wrapping_mul(0x5851F42D4C957F2D));
            // Deterministic per-op payloads (recomputable for the blocking
            // reference below).
            let payload = |op: usize| -> Vec<u64> {
                (0..3 * m)
                    .map(|k| (op * 1_000_000 + me * 1000 + k) as u64)
                    .collect()
            };
            // Blocking reference, one op at a time.
            let mut want: Vec<Vec<u64>> = Vec::new();
            for op in 0..nops {
                let mut out = vec![0u64; 3 * m];
                comm.alltoall(&payload(op), &mut out);
                want.push(out);
            }
            // All ops outstanding at once, then waited in a random
            // permutation (different on every rank).
            let reqs: Vec<a2wfft::simmpi::Request> = (0..nops)
                .map(|op| comm.ialltoallv(&payload(op), &counts, &displs, &counts, &displs))
                .collect();
            let mut order: Vec<usize> = (0..nops).collect();
            for i in (1..nops).rev() {
                order.swap(i, lr.below(i + 1));
            }
            let mut got: Vec<Vec<u64>> = vec![vec![0u64; 3 * m]; nops];
            let mut slots: Vec<Option<a2wfft::simmpi::Request>> =
                reqs.into_iter().map(Some).collect();
            for &op in &order {
                let req = slots[op].take().unwrap();
                req.wait_typed(&mut got[op]);
            }
            for op in 0..nops {
                assert_eq!(
                    want[op], got[op],
                    "case {case} rank {me} op {op}: permuted wait diverged (order {order:?})"
                );
            }
        });
    }
}

#[test]
fn prop_fft_matches_naive_dft_random_lengths() {
    let mut rng = Rng::new(5);
    for _ in 0..40 {
        let n = rng.range(1, 300);
        let x: Vec<Complex64> = (0..n).map(|_| Complex64::new(rng.f64(), rng.f64())).collect();
        let plan = FftPlan::new(n);
        let mut y = x.clone();
        plan.process(&mut y, Direction::Forward);
        let want = naive_dft(&x, Direction::Forward);
        let err = max_abs_diff(&y, &want) / (n as f64).max(1.0);
        assert!(err < 1e-11, "n={n}: err={err}");
        plan.process(&mut y, Direction::Backward);
        assert!(max_abs_diff(&x, &y) < 1e-10, "n={n}: roundtrip");
    }
}

#[test]
fn prop_alltoallw_conservation() {
    // Total "mass" (sum of all elements) is conserved by any exchange.
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let nprocs = rng.range(2, 6);
        let n0 = rng.range(nprocs, 12);
        let n1 = rng.range(nprocs, 12);
        let global = [n0, n1, rng.range(1, 6)];
        World::run(nprocs, move |comm| {
            use a2wfft::simmpi::collective::ReduceOp;
            let m = comm.size();
            let me = comm.rank();
            let sizes_a = [decompose(global[0], m, me).0, global[1], global[2]];
            let sizes_b = [global[0], decompose(global[1], m, me).0, global[2]];
            let a: Vec<f64> =
                (0..sizes_a.iter().product::<usize>()).map(|k| (me * 31 + k) as f64).collect();
            let mut b = vec![0.0f64; sizes_b.iter().product()];
            exchange(&comm, &a, &sizes_a, 1, &mut b, &sizes_b, 0);
            let mut sums = [a.iter().sum::<f64>(), b.iter().sum::<f64>()];
            comm.allreduce_f64(&mut sums, ReduceOp::Sum);
            assert!((sums[0] - sums[1]).abs() < 1e-9, "mass not conserved");
        });
    }
}
