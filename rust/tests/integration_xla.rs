//! Three-layer integration: the distributed transform with serial-FFT
//! leaves on the AOT JAX+Pallas artifacts (PJRT), validated against the
//! native f64 engine. Skips gracefully when `make artifacts` has not run.

use a2wfft::fft::{max_abs_diff, Complex64, NativeFft};
use a2wfft::pfft::{Kind, PfftPlan, RedistMethod};
use a2wfft::runtime::XlaFftEngine;
use a2wfft::simmpi::World;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn distributed_c2c_xla_vs_native() {
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let global = vec![16usize, 32, 16];
    World::run(2, |comm| {
        let mut plan =
            PfftPlan::<f64>::with_dims(&comm, &global, &[2], Kind::C2c, RedistMethod::Alltoallw);
        let input: Vec<Complex64> = (0..plan.input_len())
            .map(|k| Complex64::new(((k * 5) % 11) as f64 / 11.0, ((k * 3) % 7) as f64 / 7.0))
            .collect();
        let mut native = NativeFft::<f64>::new();
        let mut want = vec![Complex64::ZERO; plan.output_len()];
        plan.forward(&mut native, &input, &mut want);
        let mut xeng = XlaFftEngine::load(&artifacts_dir()).expect("artifacts");
        let mut got = vec![Complex64::ZERO; plan.output_len()];
        plan.forward(&mut xeng, &input, &mut got);
        let err = max_abs_diff(&want, &got);
        assert!(err < 2e-2, "rank {}: engines diverged: {err}", comm.rank());
        // Full roundtrip on the XLA engine alone.
        let mut back = vec![Complex64::ZERO; plan.input_len()];
        plan.backward(&mut xeng, &got, &mut back);
        let rerr = max_abs_diff(&input, &back);
        assert!(rerr < 1e-3, "rank {}: xla roundtrip: {rerr}", comm.rank());
    });
}

#[test]
fn distributed_r2c_on_xla_engine() {
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let global = vec![16usize, 16, 32];
    World::run(4, |comm| {
        let mut plan =
            PfftPlan::<f64>::with_dims(&comm, &global, &[2, 2], Kind::R2c, RedistMethod::Alltoallw);
        let mut xeng = XlaFftEngine::load(&artifacts_dir()).expect("artifacts");
        let input: Vec<f64> =
            (0..plan.input_len()).map(|k| ((k % 19) as f64 - 9.0) / 9.0).collect();
        let mut spec = vec![Complex64::ZERO; plan.output_len()];
        plan.forward_r2c(&mut xeng, &input, &mut spec);
        let mut back = vec![0.0f64; plan.input_len()];
        plan.backward_c2r(&mut xeng, &spec, &mut back);
        let err =
            input.iter().zip(&back).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-4, "rank {}: r2c/c2r roundtrip on xla engine: {err}", comm.rank());
    });
}
