"""AOT compile path: lower the Layer-2 batched FFT to HLO **text**
artifacts the rust runtime loads via the PJRT C API.

Why HLO text and not ``lowered.compile()`` / serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The HLO *text* parser reassigns ids on load, so text round-trips cleanly
(see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  fft_{fwd|bwd}_b{B}_n{N}.hlo.txt   one module per (direction, batch, n)
  manifest.tsv                      name, direction, batch, n, file

Run once at build time (``make artifacts``); python never runs on the
request path.
"""

from __future__ import annotations

import argparse
import os

from . import model

# Default artifact set: the serial-FFT line lengths the rust coordinator's
# examples and benches ship to the XLA engine. Batch is the padded row
# block (rust pads partial batches with zeros).
DEFAULT_BATCH = 64
DEFAULT_SIZES = (16, 32, 64, 128)


def emit(out_dir: str, batch: int, sizes, force: bool = False) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    written = []
    for n in sizes:
        for forward in (True, False):
            tag = "fwd" if forward else "bwd"
            name = f"fft_{tag}_b{batch}_n{n}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            rows.append((name, tag, batch, n, os.path.basename(path)))
            if os.path.exists(path) and not force:
                continue
            text = model.lowered_hlo_text(batch, n, forward)
            with open(path, "w") as f:
                f.write(text)
            written.append(path)
    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# name\tdir\tbatch\tn\tfile\n")
        for row in rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--sizes", type=int, nargs="*", default=list(DEFAULT_SIZES))
    ap.add_argument("--force", action="store_true", help="re-lower even if files exist")
    args = ap.parse_args()
    written = emit(args.out_dir, args.batch, args.sizes, args.force)
    print(f"artifacts: {len(written)} modules written to {os.path.abspath(args.out_dir)}")


if __name__ == "__main__":
    main()
