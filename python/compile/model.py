"""Layer-2 JAX model: the batched serial FFT the rust coordinator executes
through PJRT on its hot path.

The transform of ``(batch, n)`` complex rows is built from the Layer-1
Pallas kernels via the four-step (Cooley-Tukey ``n = n1 * n2``)
factorization:

  1. view rows as ``(batch, n1, n2)`` (j = j1 * n2 + j2);
  2. DFT over the ``n1`` axis (a batched n1-point DFT matmul);
  3. multiply by twiddles ``W_n^{k1 j2}``;
  4. DFT over the ``n2`` axis;
  5. output index is ``k = k2 * n1 + k1`` — a transpose + reshape.

Each DFT step is a dense matmul against a precomputed DFT matrix
(kernels/dft.py), so the compute lands on the MXU. For prime ``n`` the
model falls back to the single O(n^2) DFT matmul, which is still one dense
matmul — acceptable for the sizes the coordinator ships to this engine.

Complex data crosses the rust <-> XLA boundary as separate float32
real/imag planes (the ``xla`` crate's Literal API has no complex dtype).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import dft


def _four_step(xr, xi, n1: int, n2: int, sign: float, block_b: int):
    """Four-step FFT of (b, n1*n2) rows; returns (b, n1*n2) planes."""
    b = xr.shape[0]
    n = n1 * n2
    # Step 1: (b, n) -> (b, n1, n2), j = j1 * n2 + j2.
    xr3 = xr.reshape(b, n1, n2)
    xi3 = xi.reshape(b, n1, n2)
    # Step 2: DFT over axis 1 (length n1). Move n1 last: (b, n2, n1).
    f1r, f1i = dft.dft_matrix(n1, sign)
    tr = jnp.swapaxes(xr3, 1, 2).reshape(b * n2, n1)
    ti = jnp.swapaxes(xi3, 1, 2).reshape(b * n2, n1)
    yr, yi = dft.dft_matmul(tr, ti, f1r, f1i, block_b)
    # Back to (b, n1(k1), n2(j2)).
    yr = jnp.swapaxes(yr.reshape(b, n2, n1), 1, 2)
    yi = jnp.swapaxes(yi.reshape(b, n2, n1), 1, 2)
    # Step 3: twiddles T[k1, j2] = W_n^{k1 j2}.
    twr, twi = dft.four_step_twiddles(n1, n2, sign)
    yr, yi = dft.twiddle_multiply(yr, yi, twr, twi, block_b)
    # Step 4: DFT over axis 2 (length n2).
    f2r, f2i = dft.dft_matrix(n2, sign)
    zr, zi = dft.dft_matmul(
        yr.reshape(b * n1, n2), yi.reshape(b * n1, n2), f2r, f2i, block_b
    )
    # Step 5: output ordering k = k2 * n1 + k1: (b, k1, k2) -> (b, k2, k1).
    zr = jnp.swapaxes(zr.reshape(b, n1, n2), 1, 2).reshape(b, n)
    zi = jnp.swapaxes(zi.reshape(b, n1, n2), 1, 2).reshape(b, n)
    return zr, zi


def fft_rows(xr, xi, sign: float = -1.0, block_b: int = dft.DEFAULT_BLOCK_B):
    """Unnormalized FFT of (batch, n) complex rows (planes in/out).

    ``sign=-1`` forward; ``sign=+1`` is the *unnormalized* backward
    transform (callers scale by 1/n; :func:`ifft_rows` does it for you).
    """
    b, n = xr.shape
    if n == 1:
        return xr, xi
    n1, n2 = dft.split_length(n)
    if n1 == 1:
        # Prime length: single dense DFT matmul.
        fr, fi = dft.dft_matrix(n, sign)
        return dft.dft_matmul(xr, xi, fr, fi, block_b)
    return _four_step(xr, xi, n1, n2, sign, block_b)


def ifft_rows(xr, xi, block_b: int = dft.DEFAULT_BLOCK_B):
    """Normalized (1/n) inverse FFT of (batch, n) rows."""
    n = xr.shape[-1]
    yr, yi = fft_rows(xr, xi, sign=+1.0, block_b=block_b)
    return yr / n, yi / n


def make_fft_fn(batch: int, n: int, forward: bool):
    """A closed (batch, n)-static function suitable for AOT lowering.

    Returns ``(xr, xi) -> (yr, yi)`` over float32 (batch, n) planes.
    Backward includes the 1/n normalization, matching the rust native
    engine's convention.
    """
    del batch  # shapes are pinned by the example args at lowering time

    def fn(xr, xi):
        if forward:
            return fft_rows(xr, xi, sign=-1.0)
        return ifft_rows(xr, xi)

    return fn


@functools.lru_cache(maxsize=None)
def lowered_hlo_text(batch: int, n: int, forward: bool) -> str:
    """Lower the (batch, n) transform to HLO text (the AOT interchange
    format — see aot.py for why text, not serialized proto)."""
    from jax._src.lib import xla_client as xc

    spec = jax.ShapeDtypeStruct((batch, n), jnp.float32)
    lowered = jax.jit(make_fft_fn(batch, n, forward)).lower(spec, spec)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
