"""Pure-jnp oracle for the Pallas kernels and the L2 model.

Everything here is straight ``jnp.fft`` (or explicit matrix DFT) — no
Pallas, no custom lowering — and is what pytest compares kernel and model
outputs against.
"""

from __future__ import annotations

import jax.numpy as jnp


def fft_ref(xr, xi):
    """Forward complex FFT along the last axis via jnp.fft, planes in/out."""
    y = jnp.fft.fft(xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64))
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def ifft_ref(xr, xi):
    """Backward (1/N-scaled) complex FFT along the last axis via jnp.fft."""
    y = jnp.fft.ifft(xr.astype(jnp.complex64) + 1j * xi.astype(jnp.complex64))
    return jnp.real(y).astype(jnp.float32), jnp.imag(y).astype(jnp.float32)


def dft_matmul_ref(xr, xi, sign: float = -1.0):
    """O(N^2) matrix DFT of (batch, n) rows — the kernel-level oracle."""
    n = xr.shape[-1]
    j = jnp.arange(n)
    theta = sign * 2.0 * jnp.pi * ((j[:, None] * j[None, :]) % n) / n
    fr = jnp.cos(theta).astype(jnp.float32)
    fi = jnp.sin(theta).astype(jnp.float32)
    yr = xr @ fr - xi @ fi
    yi = xr @ fi + xi @ fr
    return yr, yi
