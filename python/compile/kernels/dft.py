"""Layer-1 Pallas kernels: the batched DFT-stage matmul.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the serial-FFT leaf is
expressed as dense matrix multiplication against precomputed DFT matrices so
the hot loop is MXU (systolic-array) work rather than branchy butterflies.
Complex arithmetic is carried as separate real/imaginary planes and each
complex matmul uses the 3-real-matmul Karatsuba decomposition:

    t1 = xr @ Fr,  t2 = xi @ Fi,  t3 = (xr + xi) @ (Fr + Fi)
    yr = t1 - t2,  yi = t3 - t1 - t2

The kernel computes one (block_b, n) output panel per grid step; the DFT
matrix (n, n) panels stay VMEM-resident across the batch sweep (BlockSpec
index maps pin them to block (0, 0)).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness is what the build-time pytest checks.
Real-TPU VMEM/MXU estimates live in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. 128 rows x 128-lane rows is the natural MXU panel; we keep it
# modest so small batches do not over-pad.
DEFAULT_BLOCK_B = 64


def _dft_matmul_kernel(xr_ref, xi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    """One grid step: (block_b, n) complex rows times (n, n) DFT matrix.

    ``F`` is passed already transposed (``F[k, j] -> F^T[j, k]``) so the
    contraction is a plain row-major matmul ``x (b, n) @ Ft (n, n)``.
    """
    xr = xr_ref[...]
    xi = xi_ref[...]
    fr = fr_ref[...]
    fi = fi_ref[...]
    # Karatsuba: 3 real matmuls instead of 4.
    t1 = jnp.dot(xr, fr, preferred_element_type=jnp.float32)
    t2 = jnp.dot(xi, fi, preferred_element_type=jnp.float32)
    t3 = jnp.dot(xr + xi, fr + fi, preferred_element_type=jnp.float32)
    or_ref[...] = t1 - t2
    oi_ref[...] = t3 - t1 - t2


@functools.partial(jax.jit, static_argnums=(4,))
def dft_matmul(xr, xi, ftr, fti, block_b: int = DEFAULT_BLOCK_B):
    """Batched complex DFT-stage: ``y[b, k] = sum_j x[b, j] * F[k, j]``.

    Args:
      xr, xi: (batch, n) float32 — real/imag planes of the input rows.
      ftr, fti: (n, n) float32 — the *transposed* DFT matrix planes
        (``ftr[j, k] = Re W^{jk}``), so the kernel contracts ``x @ Ft``.
      block_b: batch tile per grid step (batch must divide evenly; callers
        pad — see :func:`pad_batch`).

    Returns:
      (yr, yi): (batch, n) float32.
    """
    b, n = xr.shape
    assert xr.shape == xi.shape
    assert ftr.shape == (n, n) and fti.shape == (n, n)
    block_b = choose_block(b, block_b)
    grid = (b // block_b,)
    row_spec = pl.BlockSpec((block_b, n), lambda i: (i, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((b, n), jnp.float32)
    return pl.pallas_call(
        _dft_matmul_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, mat_spec, mat_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(xr, xi, ftr, fti)


def _twiddle_kernel(xr_ref, xi_ref, tr_ref, ti_ref, or_ref, oi_ref):
    """Pointwise complex multiply of a (block_b, n1, n2) panel by the
    (n1, n2) four-step twiddle factors."""
    xr = xr_ref[...]
    xi = xi_ref[...]
    tr = tr_ref[...]
    ti = ti_ref[...]
    or_ref[...] = xr * tr - xi * ti
    oi_ref[...] = xr * ti + xi * tr


@functools.partial(jax.jit, static_argnums=(4,))
def twiddle_multiply(xr, xi, tr, ti, block_b: int = DEFAULT_BLOCK_B):
    """Elementwise multiply by twiddles: x (b, n1, n2) * t (n1, n2)."""
    b, n1, n2 = xr.shape
    assert tr.shape == (n1, n2)
    block_b = choose_block(b, block_b)
    grid = (b // block_b,)
    row_spec = pl.BlockSpec((block_b, n1, n2), lambda i: (i, 0, 0))
    tw_spec = pl.BlockSpec((n1, n2), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct((b, n1, n2), jnp.float32)
    return pl.pallas_call(
        _twiddle_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, tw_spec, tw_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[out_shape, out_shape],
        interpret=True,
    )(xr, xi, tr, ti)


def choose_block(b: int, block_b: int) -> int:
    """Largest divisor of ``b`` that is ``<= block_b`` (grid tiling needs
    the batch to divide evenly; ``b`` is static at trace time)."""
    block_b = min(block_b, b)
    while b % block_b != 0:
        block_b -= 1
    return max(block_b, 1)


def dft_matrix(n: int, sign: float = -1.0):
    """Transposed DFT matrix planes ``Ft[j, k] = exp(sign * 2 pi i jk / n)``
    as float32 (re, im). ``sign=-1`` is the forward transform."""
    j = jnp.arange(n)
    # (j * k) mod n computed in int space to keep angles exact for large n.
    jk = (j[:, None] * j[None, :]) % n
    # jk < n, so theta < 2*pi and float32 keeps full precision.
    theta = sign * 2.0 * jnp.pi * jk.astype(jnp.float32) / n
    return jnp.cos(theta), jnp.sin(theta)


def four_step_twiddles(n1: int, n2: int, sign: float = -1.0):
    """Four-step twiddle factors ``T[k1, j2] = exp(sign 2 pi i k1 j2 / n)``
    with ``n = n1 * n2``, as float32 (re, im)."""
    n = n1 * n2
    k1 = jnp.arange(n1)
    j2 = jnp.arange(n2)
    prod = (k1[:, None] * j2[None, :]) % n
    theta = sign * 2.0 * jnp.pi * prod.astype(jnp.float32) / n
    return jnp.cos(theta), jnp.sin(theta)


def split_length(n: int) -> tuple[int, int]:
    """Factor ``n = n1 * n2`` with ``n1 <= n2`` as square as possible (the
    four-step split). Returns (1, n) for primes."""
    best = (1, n)
    f = 1
    while f * f <= n:
        if n % f == 0:
            best = (f, n // f)
        f += 1
    return best


def pad_batch(x, block_b: int):
    """Pad axis 0 up to a multiple of ``block_b`` (zeros)."""
    b = x.shape[0]
    rem = (-b) % block_b
    if rem == 0:
        return x
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)
