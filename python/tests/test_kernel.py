"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and data; every case asserts allclose against the
reference at float32 tolerances. This is the CORE correctness signal for
the compile path — the rust runtime executes exactly these kernels after
AOT lowering.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import dft, ref

RTOL = 2e-4
ATOL = 2e-4


def rows(batch, n, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((batch, n)).astype(np.float32),
        rng.standard_normal((batch, n)).astype(np.float32),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=48),
    batch=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dft_matmul_matches_matrix_dft(n, batch, seed):
    xr, xi = rows(batch, n, seed)
    fr, fi = dft.dft_matrix(n)
    yr, yi = dft.dft_matmul(jnp.array(xr), jnp.array(xi), fr, fi)
    wr, wi = ref.dft_matmul_ref(xr, xi)
    scale = max(1.0, float(np.abs(wr).max()), float(np.abs(wi).max()))
    np.testing.assert_allclose(np.array(yr) / scale, wr / scale, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.array(yi) / scale, wi / scale, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 8, 16, 32]),
    batch=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_dft_matmul_matches_jnp_fft(n, batch, seed):
    xr, xi = rows(batch, n, seed)
    fr, fi = dft.dft_matrix(n)
    yr, yi = dft.dft_matmul(jnp.array(xr), jnp.array(xi), fr, fi)
    wr, wi = ref.fft_ref(xr, xi)
    np.testing.assert_allclose(np.array(yr), np.array(wr), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(yi), np.array(wi), rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    n1=st.integers(min_value=1, max_value=12),
    n2=st.integers(min_value=1, max_value=12),
    batch=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_twiddle_multiply_is_complex_mul(n1, n2, batch, seed):
    rng = np.random.default_rng(seed)
    xr = rng.standard_normal((batch, n1, n2)).astype(np.float32)
    xi = rng.standard_normal((batch, n1, n2)).astype(np.float32)
    tr, ti = dft.four_step_twiddles(n1, n2)
    yr, yi = dft.twiddle_multiply(jnp.array(xr), jnp.array(xi), tr, ti)
    t = np.array(tr) + 1j * np.array(ti)
    w = (xr + 1j * xi) * t[None]
    np.testing.assert_allclose(np.array(yr), w.real, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.array(yi), w.imag, rtol=RTOL, atol=ATOL)


def test_dft_matrix_is_unitary_up_to_n():
    for n in [2, 3, 8, 15]:
        fr, fi = dft.dft_matrix(n, -1.0)
        f = np.array(fr) + 1j * np.array(fi)
        prod = f @ f.conj().T
        np.testing.assert_allclose(prod, n * np.eye(n), atol=1e-3)


def test_forward_backward_matrices_conjugate():
    fr_f, fi_f = dft.dft_matrix(12, -1.0)
    fr_b, fi_b = dft.dft_matrix(12, +1.0)
    np.testing.assert_allclose(np.array(fr_f), np.array(fr_b), atol=1e-6)
    np.testing.assert_allclose(np.array(fi_f), -np.array(fi_b), atol=1e-6)


@given(b=st.integers(min_value=1, max_value=500), block=st.integers(min_value=1, max_value=128))
def test_choose_block_divides(b, block):
    got = dft.choose_block(b, block)
    assert 1 <= got <= min(b, block)
    assert b % got == 0


@given(n=st.integers(min_value=1, max_value=10_000))
def test_split_length_factors(n):
    n1, n2 = dft.split_length(n)
    assert n1 * n2 == n
    assert n1 <= n2


def test_pad_batch():
    x = jnp.ones((5, 3))
    y = dft.pad_batch(x, 4)
    assert y.shape == (8, 3)
    assert float(y[5:].sum()) == 0.0
    z = dft.pad_batch(x, 5)
    assert z.shape == (5, 3)


@pytest.mark.parametrize("block", [1, 3, 16, 64])
def test_block_size_does_not_change_result(block):
    xr, xi = rows(24, 16, 7)
    fr, fi = dft.dft_matrix(16)
    yr0, yi0 = dft.dft_matmul(jnp.array(xr), jnp.array(xi), fr, fi, 64)
    yr1, yi1 = dft.dft_matmul(jnp.array(xr), jnp.array(xi), fr, fi, block)
    np.testing.assert_allclose(np.array(yr0), np.array(yr1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.array(yi0), np.array(yi1), rtol=1e-6, atol=1e-6)
