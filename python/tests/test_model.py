"""L2 correctness: the four-step Pallas-backed FFT model vs jnp.fft, plus
the AOT lowering contract (HLO text shape) the rust runtime relies on."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def rows(batch, n, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.standard_normal((batch, n)).astype(np.float32),
        rng.standard_normal((batch, n)).astype(np.float32),
    )


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([2, 4, 6, 8, 12, 15, 16, 20, 32, 36, 64, 100, 128, 13, 17]),
    batch=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fft_rows_matches_jnp(n, batch, seed):
    xr, xi = rows(batch, n, seed)
    yr, yi = model.fft_rows(jnp.array(xr), jnp.array(xi))
    wr, wi = ref.fft_ref(xr, xi)
    scale = max(1.0, float(np.abs(np.array(wr)).max()), float(np.abs(np.array(wi)).max()))
    np.testing.assert_allclose(np.array(yr) / scale, np.array(wr) / scale, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(yi) / scale, np.array(wi) / scale, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 9, 16, 25, 64, 128]),
    batch=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_roundtrip_identity(n, batch, seed):
    xr, xi = rows(batch, n, seed)
    yr, yi = model.fft_rows(jnp.array(xr), jnp.array(xi))
    br, bi = model.ifft_rows(yr, yi)
    np.testing.assert_allclose(np.array(br), xr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(bi), xi, rtol=1e-4, atol=1e-4)


def test_ifft_matches_jnp():
    xr, xi = rows(6, 32, 3)
    yr, yi = model.ifft_rows(jnp.array(xr), jnp.array(xi))
    wr, wi = ref.ifft_ref(xr, xi)
    np.testing.assert_allclose(np.array(yr), np.array(wr), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.array(yi), np.array(wi), rtol=1e-4, atol=1e-5)


def test_parseval():
    xr, xi = rows(4, 64, 11)
    yr, yi = model.fft_rows(jnp.array(xr), jnp.array(xi))
    ex = float((xr**2 + xi**2).sum())
    ey = float((np.array(yr) ** 2 + np.array(yi) ** 2).sum()) / 64
    assert abs(ex - ey) / ex < 1e-4


def test_impulse_response_flat():
    n = 16
    xr = np.zeros((1, n), np.float32)
    xr[0, 0] = 1.0
    xi = np.zeros_like(xr)
    yr, yi = model.fft_rows(jnp.array(xr), jnp.array(xi))
    np.testing.assert_allclose(np.array(yr), np.ones((1, n), np.float32), atol=1e-5)
    np.testing.assert_allclose(np.array(yi), np.zeros((1, n), np.float32), atol=1e-5)


def test_linearity():
    ar, ai = rows(3, 24, 1)
    br, bi = rows(3, 24, 2)
    fa = model.fft_rows(jnp.array(ar), jnp.array(ai))
    fb = model.fft_rows(jnp.array(br), jnp.array(bi))
    fs = model.fft_rows(jnp.array(ar + 2 * br), jnp.array(ai + 2 * bi))
    np.testing.assert_allclose(
        np.array(fs[0]), np.array(fa[0]) + 2 * np.array(fb[0]), rtol=1e-3, atol=1e-3
    )
    np.testing.assert_allclose(
        np.array(fs[1]), np.array(fa[1]) + 2 * np.array(fb[1]), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("forward", [True, False])
def test_lowered_hlo_is_text_with_entry(forward):
    text = model.lowered_hlo_text(8, 16, forward)
    assert "ENTRY" in text, "expected parseable HLO text"
    assert "f32[8,16]" in text, "expected the (batch, n) parameter shape"
    # Two outputs (re, im) as a tuple — the rust side unwraps to_tuple2.
    assert "(f32[8,16]" in text


def test_prime_path_uses_single_matmul():
    # For prime n the model takes the dense-DFT path; verify numerics there.
    xr, xi = rows(5, 13, 9)
    yr, yi = model.fft_rows(jnp.array(xr), jnp.array(xi))
    wr, wi = ref.fft_ref(xr, xi)
    np.testing.assert_allclose(np.array(yr), np.array(wr), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(yi), np.array(wi), rtol=1e-3, atol=1e-3)
